//! Property-based invariants over the core algorithms and coordinator
//! data structures, via the in-crate [`onlinesoftmax::prop`] harness.

use onlinesoftmax::exec::SchedPolicy;
use onlinesoftmax::prop::{
    forall, forall_with, Config, Gen, LogitsVec, Pair, PropResult, UsizeRange,
};
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::sample::{self, SampleSpec};
use onlinesoftmax::shard::{
    tree_reduce, GridPlan, ShardBackendKind, ShardEngine, ShardEngineConfig, ShardPartial,
    ShardPlan,
};
use onlinesoftmax::softmax::{self, fused, monoid::MD, scalar, vectorized, Algorithm};
use onlinesoftmax::topk::{heap_topk, scan_topk, TopKBuffer};

const LOGITS: LogitsVec = LogitsVec { min_len: 1, max_len: 800 };

fn close(a: f32, b: f32, rtol: f32) -> bool {
    (a - b).abs() <= 1e-9 + rtol * a.abs().max(b.abs())
}

// ---------------------------------------------------------------------------
// Softmax numeric invariants (paper §3)
// ---------------------------------------------------------------------------

#[test]
fn prop_online_softmax_is_distribution() {
    forall(&LOGITS, |x| {
        let mut y = vec![0.0; x.len()];
        vectorized::online(x, &mut y);
        let sum: f32 = y.iter().sum();
        if !y.iter().all(|p| p.is_finite() && *p >= 0.0) {
            return Err(format!("non-finite/negative probs: {y:?}"));
        }
        if !close(sum, 1.0, 1e-3) {
            return Err(format!("sum {sum} != 1"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_online_equals_safe_normalizer() {
    // Theorem 1: Algorithms 2 and 3 compute identical (m, d).
    forall(&LOGITS, |x| {
        let a = scalar::safe_normalizer(x);
        let b = scalar::online_normalizer(x);
        if a.m != b.m {
            return Err(format!("m: {} vs {}", a.m, b.m));
        }
        if !close(a.d, b.d, 1e-4) {
            return Err(format!("d: {} vs {}", a.d, b.d));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_vectorized_equals_scalar_normalizer() {
    forall(&LOGITS, |x| {
        let a = scalar::online_normalizer(x);
        let b = vectorized::online_normalizer(x);
        if a.m != b.m {
            return Err(format!("m: {} vs {}", a.m, b.m));
        }
        if !close(a.d, b.d, 1e-4) {
            return Err(format!("d: {} vs {}", a.d, b.d));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_d_bound_1_le_d_le_v() {
    // §3's safety bound survives every evaluation order we use.
    forall(&LOGITS, |x| {
        let md = vectorized::online_normalizer(x);
        if md.d < 1.0 - 1e-5 {
            return Err(format!("d = {} < 1", md.d));
        }
        if md.d > x.len() as f32 * (1.0 + 1e-5) {
            return Err(format!("d = {} > V = {}", md.d, x.len()));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_split_merge_equals_whole() {
    // ⊕ associativity in practice: any split point merges to the whole.
    let gen = Pair(LOGITS, UsizeRange(0, 100));
    forall(&gen, |(x, cut_pct)| {
        let cut = x.len() * cut_pct / 100;
        let whole = vectorized::online_normalizer(x);
        let left = vectorized::online_normalizer(&x[..cut]);
        let right = vectorized::online_normalizer(&x[cut..]);
        let merged = left.combine(right);
        if whole.m != merged.m {
            return Err(format!("m: {} vs {}", whole.m, merged.m));
        }
        if !close(whole.d, merged.d, 1e-4) {
            return Err(format!("d: {} vs {}", whole.d, merged.d));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_monoid_laws_on_random_elements() {
    struct MdGen;
    impl Gen for MdGen {
        type Value = (f32, f32);
        fn generate(&self, rng: &mut Xoshiro256pp) -> (f32, f32) {
            (rng.next_normal() * 50.0, rng.range_f32(0.0, 100.0))
        }
    }
    let gen = onlinesoftmax::prop::VecOf { inner: MdGen, min_len: 3, max_len: 3 };
    forall(&gen, |v| {
        let a = MD { m: v[0].0, d: v[0].1 };
        let b = MD { m: v[1].0, d: v[1].1 };
        let c = MD { m: v[2].0, d: v[2].1 };
        let l = a.combine(b).combine(c);
        let r = a.combine(b.combine(c));
        if l.m != r.m || !close(l.d, r.d, 1e-4) {
            return Err(format!("assoc: {l:?} vs {r:?}"));
        }
        let ab = a.combine(b);
        let ba = b.combine(a);
        if ab.m != ba.m || !close(ab.d, ba.d, 1e-5) {
            return Err(format!("comm: {ab:?} vs {ba:?}"));
        }
        let ae = a.combine(MD::IDENTITY);
        if ae.m != a.m || !close(ae.d, a.d, 1e-6) {
            return Err(format!("identity: {ae:?} vs {a:?}"));
        }
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Top-k invariants (paper §4)
// ---------------------------------------------------------------------------

#[test]
fn prop_fused_topk_equals_heap_topk() {
    let gen = Pair(LOGITS, UsizeRange(1, 32));
    forall(&gen, |(x, k)| {
        let k = (*k).min(x.len());
        let (fv, fi) = fused::online_topk(x, k);
        let (hv, hi) = heap_topk(x, k);
        // raw logits selected must coincide (value ties → same index rule)
        if fi != hi {
            return Err(format!("indices {fi:?} vs {hi:?}"));
        }
        let _ = (fv, hv);
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_buffer_merge_associative() {
    let gen = Pair(LOGITS, UsizeRange(1, 8));
    forall(&gen, |(x, k)| {
        if x.len() < 3 {
            return Ok(());
        }
        let k = *k;
        let third = x.len() / 3;
        let a = scan_topk(&x[..third], k, 0);
        let b = scan_topk(&x[third..2 * third], k, third as i64);
        let c = scan_topk(&x[2 * third..], k, 2 * third as i64);
        // (a ⊎ b) ⊎ c
        let mut left = TopKBuffer::new(k);
        left.merge(&a);
        left.merge(&b);
        left.merge(&c);
        // a ⊎ (b ⊎ c)
        let mut right_inner = TopKBuffer::new(k);
        right_inner.merge(&b);
        right_inner.merge(&c);
        let mut right = TopKBuffer::new(k);
        right.merge(&a);
        right.merge(&right_inner);
        if left.indices() != right.indices() {
            return Err(format!("{:?} vs {:?}", left.indices(), right.indices()));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_topk_probs_are_the_k_largest() {
    let gen = Pair(LOGITS, UsizeRange(1, 16));
    forall(&gen, |(x, k)| {
        let k = (*k).min(x.len());
        let (vals, idx) = fused::online_topk(x, k);
        let mut y = vec![0.0; x.len()];
        scalar::safe(x, &mut y);
        let mut sorted = y.clone();
        sorted.sort_by(|a, b| b.total_cmp(a));
        for (i, v) in vals.iter().enumerate() {
            if !close(*v, sorted[i], 1e-3) {
                return Err(format!("rank {i}: {} vs {}", v, sorted[i]));
            }
            if !close(y[idx[i] as usize], *v, 1e-4) {
                return Err(format!("idx {} does not carry value {}", idx[i], v));
            }
        }
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Shard-layer invariants (the cross-shard §3.1/§4 reduction)
// ---------------------------------------------------------------------------

#[test]
fn prop_shard_plan_partitions_exactly() {
    let gen = Pair(UsizeRange(0, 5000), UsizeRange(1, 64));
    forall(&gen, |&(v, shards)| {
        let plan = ShardPlan::with_shards(v, shards);
        let mut next = 0usize;
        for r in plan.ranges() {
            if r.start != next {
                return Err(format!("gap at {next} (v={v}, shards={shards})"));
            }
            next = r.end;
        }
        if next != v {
            return Err(format!("covers {next} of {v}"));
        }
        let lens: Vec<usize> = plan.ranges().map(|r| r.len()).collect();
        let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
        if hi - lo > 1 {
            return Err(format!("unbalanced: {lens:?}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_softmax_matches_compute() {
    // The tentpole invariant: for ANY shard count, the shard engine's
    // softmax equals the single-thread kernel within fp reassociation
    // tolerance (and the selected maxima bitwise).
    let engine = ShardEngine::new(ShardEngineConfig {
        workers: 4,
        min_shard: 1,
        threshold: 1,
        ..Default::default()
    });
    let gen = Pair(LogitsVec { min_len: 1, max_len: 600 }, UsizeRange(1, 24));
    let cfg = Config { cases: 120, ..Config::default() };
    forall_with(cfg, &gen, |(x, shards)| {
        let plan = ShardPlan::with_shards(x.len(), *shards);
        let mut sharded = vec![0.0; x.len()];
        engine.softmax_into_planned(x, &mut sharded, &plan);
        let serial = softmax::compute(x, Algorithm::Online);
        for (i, (a, b)) in sharded.iter().zip(&serial).enumerate() {
            if (a - b).abs() > 1e-9 + 1e-4 * b.abs() {
                return Err(format!("shards={shards} idx={i}: {a} vs {b}"));
            }
        }
        let sum: f32 = sharded.iter().sum();
        if !close(sum, 1.0, 1e-3) {
            return Err(format!("shards={shards}: sum {sum} != 1"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_fused_topk_matches_single_sweep() {
    let engine = ShardEngine::new(ShardEngineConfig {
        workers: 3,
        min_shard: 1,
        threshold: 1,
        ..Default::default()
    });
    let gen =
        Pair(Pair(LogitsVec { min_len: 1, max_len: 500 }, UsizeRange(1, 16)), UsizeRange(1, 12));
    let cfg = Config { cases: 120, ..Config::default() };
    forall_with(cfg, &gen, |((x, k), shards)| {
        let k = (*k).max(1);
        let plan = ShardPlan::with_shards(x.len(), *shards);
        let (sv, si) = engine.fused_topk_planned(x, k, &plan);
        let (wv, wi) = fused::online_topk(x, k);
        if si != wi {
            return Err(format!("shards={shards} k={k}: {si:?} vs {wi:?}"));
        }
        for (a, b) in sv.iter().zip(&wv) {
            if (a - b).abs() > 1e-9 + 1e-4 * a.abs().max(b.abs()) {
                return Err(format!("shards={shards} k={k}: val {a} vs {b}"));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_grid_batch_is_bitwise_identical_to_per_row_runs() {
    // The grid contract: an R×S grid batch equals R independent
    // single-row sharded runs *bitwise* — same tile boundaries → same
    // scans → same ⊕ bracketing.  Covers batch = 1, shard counts that
    // leave ragged last tiles, and k beyond the row length.
    //
    // Runs under BOTH pool scheduling policies × EVERY production scan
    // backend (scalar / vectorized / twopass): tile execution order is
    // completely different between the FIFO injector and the
    // work-stealing deques, and the per-tile kernels differ between
    // backends, but within one engine the ⊕ bracketing and the leaf
    // scan are fixed by the plan + backend — so every grid output must
    // match that engine's per-row run byte for byte, and the two
    // schedulers must agree bitwise per backend.
    let mk = |sched, backend| {
        ShardEngine::new(ShardEngineConfig {
            workers: 4,
            min_shard: 1,
            threshold: 1,
            sched,
            backend,
            ..Default::default()
        })
    };
    let engines = [
        mk(SchedPolicy::Fifo, ShardBackendKind::Scalar),
        mk(SchedPolicy::Steal, ShardBackendKind::Scalar),
        mk(SchedPolicy::Fifo, ShardBackendKind::Vectorized),
        mk(SchedPolicy::Steal, ShardBackendKind::Vectorized),
        mk(SchedPolicy::Fifo, ShardBackendKind::TwoPass),
        mk(SchedPolicy::Steal, ShardBackendKind::TwoPass),
    ];
    let gen = Pair(
        Pair(UsizeRange(1, 6), LogitsVec { min_len: 1, max_len: 400 }),
        Pair(UsizeRange(1, 9), UsizeRange(1, 12)),
    );
    let cfg = Config { cases: 80, ..Config::default() };
    forall_with(cfg, &gen, |((rows_n, x), (shards, k))| {
        let v = x.len();
        let k = (*k).max(1);
        // Derive R distinct same-length rows by rotating the generated
        // one (row 0 is the original).
        let derived: Vec<Vec<f32>> = (0..*rows_n)
            .map(|i| {
                let mut row = x.clone();
                row.rotate_left(i % v);
                row
            })
            .collect();
        let rows: Vec<&[f32]> = derived.iter().map(|r| r.as_slice()).collect();
        let plan = ShardPlan::with_shards(v, *shards);
        let grid = GridPlan::new(rows.len(), plan);

        for engine in &engines {
            let label = format!("{}/{}", engine.backend_name(), engine.sched().as_str());
            let topk = engine.fused_topk_batch_planned(&rows, k, &grid);
            let probs = engine.softmax_batch_planned(&rows, &grid);
            for (i, row) in rows.iter().enumerate() {
                let want_topk = engine.fused_topk_planned(row, k, &plan);
                if topk[i] != want_topk {
                    return Err(format!(
                        "[{label}] rows={rows_n} shards={shards} k={k} row {i}: \
                         grid topk {:?} != per-row {:?}",
                        topk[i], want_topk
                    ));
                }
                let mut want_probs = vec![0.0f32; v];
                engine.softmax_into_planned(row, &mut want_probs, &plan);
                if probs[i] != want_probs {
                    return Err(format!(
                        "[{label}] rows={rows_n} shards={shards} row {i}: grid \
                         softmax diverges from per-row run"
                    ));
                }
            }
        }
        // Cross-policy per backend: the two schedulers agree bitwise on
        // the whole batch (implied by the per-row identities above,
        // asserted directly for a sharper failure message).  Engines
        // [0]/[1] are the scalar pair, [2]/[3] the vectorized pair,
        // [4]/[5] the twopass pair.
        for pair in engines.chunks(2) {
            let tf = pair[0].fused_topk_batch_planned(&rows, k, &grid);
            let ts = pair[1].fused_topk_batch_planned(&rows, k, &grid);
            if tf != ts {
                return Err(format!(
                    "[{}] rows={rows_n} shards={shards} k={k}: fifo and steal \
                     grids diverge",
                    pair[0].backend_name()
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Backend-iteration harness: the shard layer's edge-case semantics
// (NaN / −∞ / ties / k ≥ V) must hold under EVERY registered backend,
// not just the scalar path the suite originally pinned.
// ---------------------------------------------------------------------------

/// One engine per registered [`ShardBackendKind`] (including the
/// artifacts stub, whose tiles all route through the per-tile host
/// fallback — so the fallback path inherits this whole suite too).
fn engines_for_every_backend(workers: usize) -> Vec<ShardEngine> {
    ShardBackendKind::all()
        .into_iter()
        .map(|backend| {
            ShardEngine::new(ShardEngineConfig {
                workers,
                min_shard: 1,
                threshold: 1,
                backend,
                ..Default::default()
            })
        })
        .collect()
}

#[test]
fn prop_every_backend_selects_the_single_sweep_indices() {
    let engines = engines_for_every_backend(3);
    let gen =
        Pair(Pair(LogitsVec { min_len: 1, max_len: 400 }, UsizeRange(1, 10)), UsizeRange(1, 8));
    let cfg = Config { cases: 60, ..Config::default() };
    forall_with(cfg, &gen, |((x, k), shards)| {
        let k = (*k).max(1);
        let plan = ShardPlan::with_shards(x.len(), *shards);
        let (wv, wi) = fused::online_topk(x, k);
        for engine in &engines {
            let name = engine.backend_name();
            let (sv, si) = engine.fused_topk_planned(x, k, &plan);
            if si != wi {
                return Err(format!("[{name}] shards={shards} k={k}: {si:?} vs {wi:?}"));
            }
            for (a, b) in sv.iter().zip(&wv) {
                if (a - b).abs() > 1e-9 + 1e-4 * a.abs().max(b.abs()) {
                    return Err(format!("[{name}] shards={shards} k={k}: val {a} vs {b}"));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn backend_nan_logits_are_never_selected() {
    // NaN fails every `>` comparison, so it must neither enter a top-k
    // buffer nor become a shard max — under any backend, any split.
    let mut x: Vec<f32> = (0..60).map(|i| ((i * 13) % 29) as f32 * 0.5).collect();
    for i in [1usize, 7, 20, 21, 40, 59] {
        x[i] = f32::NAN;
    }
    let want = fused::online_topk(&x, 5);
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for shards in [1usize, 2, 3, 5, 9] {
            let plan = ShardPlan::with_shards(x.len(), shards);
            let (vals, idx) = engine.fused_topk_planned(&x, 5, &plan);
            assert_eq!(idx, want.1, "[{name}] shards={shards}");
            assert!(
                idx.iter().all(|&i| !x[i as usize].is_nan()),
                "[{name}] shards={shards}: selected a NaN position"
            );
            assert!(
                vals.iter().all(|v| !v.is_nan()),
                "[{name}] shards={shards}: returned NaN probabilities"
            );
        }
    }
}

#[test]
fn backend_neg_infinity_rows_and_padding_act_as_identity() {
    let engines = engines_for_every_backend(2);
    // An all-(−∞) row selects nothing, under every backend and split.
    let ninf = vec![f32::NEG_INFINITY; 37];
    for engine in &engines {
        let name = engine.backend_name();
        for shards in [1usize, 2, 5, 16] {
            let (vals, idx) =
                engine.fused_topk_planned(&ninf, 3, &ShardPlan::with_shards(37, shards));
            assert!(
                vals.is_empty() && idx.is_empty(),
                "[{name}] shards={shards}: −∞ row must select nothing"
            );
        }
    }
    // −∞ padding merges as "no contribution": the reduced normalizer
    // matches the serial scan (m exactly, d within reassociation),
    // even when one shard is entirely padding.
    let mut padded: Vec<f32> = (0..60).map(|i| ((i * 7) % 13) as f32 - 3.0).collect();
    padded.extend(std::iter::repeat(f32::NEG_INFINITY).take(20));
    let want = scalar::online_normalizer(&padded);
    for engine in &engines {
        let name = engine.backend_name();
        for shards in [2usize, 4, 8] {
            let md = engine.normalizer_planned(&padded, &ShardPlan::with_shards(80, shards));
            assert_eq!(md.m, want.m, "[{name}] shards={shards}");
            assert!(md.d.is_finite(), "[{name}] shards={shards}: d = {}", md.d);
            assert!(
                (md.d - want.d).abs() <= 1e-4 * want.d.max(1.0),
                "[{name}] shards={shards}: {} vs {}",
                md.d,
                want.d
            );
        }
    }
}

#[test]
fn backend_ties_resolve_to_earliest_global_index() {
    // Equal logits everywhere: the selected indices must be the
    // earliest global positions regardless of backend or shard count —
    // the incumbent-wins merge convention crossing every tile boundary.
    let ties = vec![5.0f32; 64];
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for shards in [1usize, 2, 4, 7, 16] {
            let (_, idx) = engine.fused_topk_planned(&ties, 3, &ShardPlan::with_shards(64, shards));
            assert_eq!(idx, vec![0, 1, 2], "[{name}] shards={shards}");
        }
    }
}

#[test]
fn backend_k_at_or_above_v_returns_whole_distribution() {
    let x = [2.0f32, 7.0, -1.0];
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for k in [3usize, 4, 10] {
            let (vals, idx) = engine.fused_topk_planned(&x, k, &ShardPlan::with_shards(3, 2));
            assert_eq!(idx, vec![1, 0, 2], "[{name}] k={k}");
            assert_eq!(vals.len(), 3, "[{name}] k={k}");
            let sum: f32 = vals.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "[{name}] k={k}: sum={sum}");
        }
    }
}

// ---------------------------------------------------------------------------
// Seeded Gumbel-top-k sampling: the perturbation is a pure function of
// (seed, global index), so the sampled selection must be exactly as
// decomposition-invariant as the deterministic top-k — across backends,
// schedulers, shard counts, and grid-vs-per-row dispatch.
// ---------------------------------------------------------------------------

#[test]
fn prop_sampled_selection_is_identical_across_backends_schedulers_and_grids() {
    // The sampled analogue of the grid bitwise-identity tentpole test:
    // for every (scheduler × production backend) engine, the sampled
    // grid batch equals that engine's per-row sampled runs bitwise, the
    // two schedulers agree bitwise per backend, and every engine
    // selects the same indices as the unsharded single-sweep reference.
    let mk = |sched, backend| {
        ShardEngine::new(ShardEngineConfig {
            workers: 4,
            min_shard: 1,
            threshold: 1,
            sched,
            backend,
            ..Default::default()
        })
    };
    let engines = [
        mk(SchedPolicy::Fifo, ShardBackendKind::Scalar),
        mk(SchedPolicy::Steal, ShardBackendKind::Scalar),
        mk(SchedPolicy::Fifo, ShardBackendKind::Vectorized),
        mk(SchedPolicy::Steal, ShardBackendKind::Vectorized),
        mk(SchedPolicy::Fifo, ShardBackendKind::TwoPass),
        mk(SchedPolicy::Steal, ShardBackendKind::TwoPass),
    ];
    let gen = Pair(
        Pair(UsizeRange(1, 5), LogitsVec { min_len: 1, max_len: 400 }),
        Pair(UsizeRange(1, 9), Pair(UsizeRange(1, 8), UsizeRange(0, 5000))),
    );
    let cfg = Config { cases: 60, ..Config::default() };
    forall_with(cfg, &gen, |((rows_n, x), (shards, (k, seed)))| {
        let v = x.len();
        let k = (*k).max(1);
        // Exercise several temperatures, derived from the generated seed
        // so shrinking stays meaningful.
        let temperature = [0.5f32, 0.8, 1.0, 1.7][seed % 4];
        let spec = SampleSpec { seed: *seed as u64, temperature };
        let derived: Vec<Vec<f32>> = (0..*rows_n)
            .map(|i| {
                let mut row = x.clone();
                row.rotate_left(i % v);
                row
            })
            .collect();
        let rows: Vec<&[f32]> = derived.iter().map(|r| r.as_slice()).collect();
        let plan = ShardPlan::with_shards(v, *shards);
        let grid = GridPlan::new(rows.len(), plan);

        for engine in &engines {
            let label = format!("{}/{}", engine.backend_name(), engine.sched().as_str());
            let batch = engine.sampled_topk_batch_planned(&rows, k, &grid, spec);
            for (i, row) in rows.iter().enumerate() {
                let per_row = engine.sampled_topk_planned(row, k, &plan, spec);
                if batch[i] != per_row {
                    return Err(format!(
                        "[{label}] rows={rows_n} shards={shards} k={k} T={temperature} \
                         row {i}: sampled grid {:?} != per-row {:?}",
                        batch[i], per_row
                    ));
                }
                // Selection identity vs the unsharded single sweep:
                // indices exact (the perturbed ranking is pure f32, no
                // reassociation), probabilities within fp tolerance
                // (the reduced d brackets differently).
                let (wv, wi) = sample::sampled_topk(row, k, spec);
                if per_row.1 != wi {
                    return Err(format!(
                        "[{label}] shards={shards} k={k} T={temperature} row {i}: \
                         sampled indices {:?} vs single-sweep {wi:?}",
                        per_row.1
                    ));
                }
                for (a, b) in per_row.0.iter().zip(&wv) {
                    if (a - b).abs() > 1e-9 + 1e-4 * a.abs().max(b.abs()) {
                        return Err(format!(
                            "[{label}] shards={shards} row {i}: sampled prob {a} vs {b}"
                        ));
                    }
                }
            }
        }
        // Cross-policy bitwise agreement per backend pair.
        for pair in engines.chunks(2) {
            let tf = pair[0].sampled_topk_batch_planned(&rows, k, &grid, spec);
            let ts = pair[1].sampled_topk_batch_planned(&rows, k, &grid, spec);
            if tf != ts {
                return Err(format!(
                    "[{}] rows={rows_n} shards={shards} k={k} T={temperature}: \
                     fifo and steal sampled grids diverge",
                    pair[0].backend_name()
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn sampled_nan_logits_are_never_selected() {
    // NaN perturbs to NaN, which fails both the fast reject and every
    // bubble comparison — under any backend, any split, any seed.
    let mut x: Vec<f32> = (0..60).map(|i| ((i * 13) % 29) as f32 * 0.5).collect();
    for i in [1usize, 7, 20, 21, 40, 59] {
        x[i] = f32::NAN;
    }
    let spec = SampleSpec { seed: 77, temperature: 0.9 };
    let (want_vals, want_idx) = sample::sampled_topk(&x, 5, spec);
    assert!(want_idx.iter().all(|&i| !x[i as usize].is_nan()));
    assert!(want_vals.iter().all(|v| !v.is_nan()));
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for shards in [1usize, 2, 3, 5, 9] {
            let plan = ShardPlan::with_shards(x.len(), shards);
            let (vals, idx) = engine.sampled_topk_planned(&x, 5, &plan, spec);
            assert_eq!(idx, want_idx, "[{name}] shards={shards}");
            assert!(
                vals.iter().all(|v| !v.is_nan()),
                "[{name}] shards={shards}: returned NaN probabilities"
            );
        }
    }
}

#[test]
fn sampled_neg_infinity_rows_select_nothing() {
    // −∞ + Gumbel = −∞: vocabulary padding stays unsampleable, so an
    // all-padding row selects nothing under every backend and split.
    let ninf = vec![f32::NEG_INFINITY; 37];
    let spec = SampleSpec { seed: 3, temperature: 1.2 };
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for shards in [1usize, 2, 5, 16] {
            let (vals, idx) =
                engine.sampled_topk_planned(&ninf, 3, &ShardPlan::with_shards(37, shards), spec);
            assert!(
                vals.is_empty() && idx.is_empty(),
                "[{name}] shards={shards}: −∞ row must sample nothing"
            );
        }
    }
}

#[test]
fn sampled_ties_resolve_by_perturbation_not_position() {
    // Equal logits everywhere: the selection is decided purely by the
    // per-index Gumbel draws, and must be identical across every
    // backend and shard count (same draws → same ranking), matching the
    // whole-row single sweep.
    let ties = vec![5.0f32; 64];
    let spec = SampleSpec { seed: 11, temperature: 1.0 };
    let (_, want) = sample::sampled_topk(&ties, 3, spec);
    // Greedy would pick [0, 1, 2]; sampling must not (the draw for this
    // seed does not happen to rank the first three positions on top —
    // pinned so a silently-greedy regression cannot pass).
    assert_ne!(want, vec![0, 1, 2], "fixture seed degenerated to the greedy order");
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for shards in [1usize, 2, 4, 7, 16] {
            let (_, idx) =
                engine.sampled_topk_planned(&ties, 3, &ShardPlan::with_shards(64, shards), spec);
            assert_eq!(idx, want, "[{name}] shards={shards}");
        }
    }
}

#[test]
fn sampled_k_at_or_above_v_returns_whole_distribution() {
    // k ≥ V: every finite token is sampled (a permutation of the
    // vocabulary, ordered by perturbed score) and the reported
    // untempered probabilities still sum to 1.
    let x = [2.0f32, 7.0, -1.0];
    let spec = SampleSpec { seed: 21, temperature: 0.6 };
    for engine in &engines_for_every_backend(2) {
        let name = engine.backend_name();
        for k in [3usize, 4, 10] {
            let (vals, idx) =
                engine.sampled_topk_planned(&x, k, &ShardPlan::with_shards(3, 2), spec);
            assert_eq!(vals.len(), 3, "[{name}] k={k}");
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2], "[{name}] k={k}: not a vocab permutation");
            let sum: f32 = vals.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "[{name}] k={k}: sum={sum}");
        }
    }
}

#[test]
fn prop_tree_reduce_is_bracketing_invariant() {
    // ⊕ associativity at the partial level: the pairwise tree and the
    // sequential left fold agree for any shard decomposition.
    let gen =
        Pair(Pair(LogitsVec { min_len: 2, max_len: 400 }, UsizeRange(1, 8)), UsizeRange(2, 10));
    forall(&gen, |((x, k), shards)| {
        let k = (*k).max(1);
        let plan = ShardPlan::with_shards(x.len(), *shards);
        let parts: Vec<ShardPartial> = plan
            .ranges()
            .map(|r| ShardPartial::scan(&x[r.start..r.end], k, r.start as i64))
            .collect();
        let tree = tree_reduce(parts.clone());
        let seq = parts.into_iter().reduce(ShardPartial::merge).unwrap();
        if tree.md.m != seq.md.m {
            return Err(format!("m: {} vs {}", tree.md.m, seq.md.m));
        }
        if !close(tree.md.d, seq.md.d, 1e-4) {
            return Err(format!("d: {} vs {}", tree.md.d, seq.md.d));
        }
        if tree.topk.indices() != seq.topk.indices() {
            return Err(format!("{:?} vs {:?}", tree.topk.indices(), seq.topk.indices()));
        }
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Coordinator data-structure invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_preserves_fifo_and_loses_nothing() {
    use onlinesoftmax::coordinator::{BatchPolicy, Batcher, Payload, Request};
    use onlinesoftmax::exec::oneshot;
    use std::time::Duration;

    let gen = Pair(UsizeRange(1, 64), UsizeRange(1, 16));
    let cfg = Config { cases: 40, ..Config::default() };
    forall_with(cfg, &gen, |&(n, max_batch)| {
        let b = Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(0),
            queue_capacity: n.max(max_batch),
        });
        for id in 0..n as u64 {
            let (tx, _rx) = oneshot();
            b.submit(Request::new(id, Payload::Softmax { logits: vec![] }, tx))
                .map_err(|_| "submit failed".to_string())?;
        }
        let mut seen = Vec::new();
        while b.depth() > 0 {
            let (_, batch, _) = b.next_batch().ok_or("unexpected end")?;
            if batch.len() > max_batch {
                return Err(format!("batch of {} exceeds max {}", batch.len(), max_batch));
            }
            seen.extend(batch.iter().map(|r| r.id));
        }
        let want: Vec<u64> = (0..n as u64).collect();
        if seen != want {
            return Err(format!("ids reordered/lost: {seen:?}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_shrinking_produces_minimal_counterexample() {
    // Meta-test: the harness shrinks a planted failure toward minimum.
    let gen = UsizeRange(0, 10_000);
    let result = forall(&gen, |&n| {
        if n < 1000 {
            Ok(())
        } else {
            Err("too big".into())
        }
    });
    match result {
        PropResult::Fail { minimal, .. } => assert!(minimal <= 1500, "minimal={minimal}"),
        PropResult::Pass { .. } => panic!("must fail"),
    }
}
