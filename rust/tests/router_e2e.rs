//! Router-tier e2e: a router coordinator fanning vocabulary shards
//! over in-process worker servers must serve results **bitwise
//! identical** to a single-process coordinator, across every shard
//! backend and both pool schedulers — and must survive a dead worker
//! by requeuing its slice onto a healthy peer.
//!
//! Topology per case: three host-backend worker `Server`s on loopback
//! (each a stock `osmax` server with an advisory `--worker-slice`),
//! one router-backend coordinator pointed at them, and one
//! single-process host-backend reference coordinator.  The reference
//! pins `host_shards = 3` so its auto plan is exactly the router's
//! `ShardPlan::with_shards(vocab, 3)` — same ranges, same ⊕
//! bracketing, hence bitwise-equal results.
//!
//! The SIGKILL-a-real-process rendition lives in CI's multi-process
//! leg; here worker death is a connection-refused address, which
//! drives the same exclude → requeue path deterministically.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use onlinesoftmax::config::{BackendKind, ServeConfig, ServingMode};
use onlinesoftmax::coordinator::{Coordinator, Payload, Reply, RequestOptions};
use onlinesoftmax::exec::SchedPolicy;
use onlinesoftmax::metrics;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::Server;
use onlinesoftmax::shard::{ShardBackendKind, ShardPlan};

const TIMEOUT: Duration = Duration::from_secs(60);
const VOCAB: usize = 2048;
const HIDDEN: usize = 32;
const WORKERS: usize = 3;

/// Shared kernel/plan config: vocab above the shard threshold so the
/// sharded path engages, and `host_shards = 3` so the single-process
/// plan equals the router's 3-worker plan (the bitwise-identity
/// precondition).
fn base_cfg(backend: ShardBackendKind, sched: SchedPolicy) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.mode = ServingMode::Online;
    cfg.vocab = VOCAB;
    cfg.hidden = HIDDEN;
    cfg.host_shards = WORKERS;
    cfg.shard_threshold = 512;
    cfg.workers = 2;
    cfg.max_wait = Duration::from_micros(500);
    cfg.shard_backend = backend;
    cfg.pool_sched = sched;
    cfg
}

struct TestWorker {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

impl TestWorker {
    fn spawn(mut cfg: ServeConfig, slice: (usize, usize)) -> TestWorker {
        cfg.addr = "127.0.0.1:0".into();
        cfg.worker_slice = Some(slice);
        let coord = Arc::new(Coordinator::start(&cfg).expect("worker coordinator"));
        let server = Server::bind(&cfg.addr, coord, 8).expect("worker bind");
        let addr = server.local_addr().expect("worker addr").to_string();
        let stop = server.stop_handle();
        let thread = std::thread::spawn(move || {
            let _ = server.serve();
        });
        TestWorker { addr, stop, thread }
    }

    fn halt(self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.thread.join();
    }
}

/// Three workers, each advertising the slice the router's plan will
/// actually send it.
fn spawn_workers(cfg: &ServeConfig) -> Vec<TestWorker> {
    ShardPlan::with_shards(VOCAB, WORKERS)
        .ranges()
        .map(|r| TestWorker::spawn(cfg.clone(), (r.start, r.end)))
        .collect()
}

fn router_coord(cfg: &ServeConfig, worker_addrs: Vec<String>) -> Coordinator {
    let mut rc = cfg.clone();
    rc.backend = BackendKind::Router;
    rc.router_workers = worker_addrs;
    rc.router_probe_ms = 200;
    rc.router_shard_timeout_ms = 10_000;
    Coordinator::start(&rc).expect("router coordinator")
}

/// A loopback address that refuses connections: bind, read the port,
/// drop the listener.
fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

fn assert_bitwise(a: &Reply, b: &Reply, what: &str) {
    match (a, b) {
        (Reply::Softmax { probs: pa }, Reply::Softmax { probs: pb }) => {
            assert_eq!(pa.len(), pb.len(), "{what}: prob lengths");
            for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: prob {i}: {x} vs {y}");
            }
        }
        (Reply::TopK { vals: va, idx: ia }, Reply::TopK { vals: vb, idx: ib }) => {
            assert_eq!(ia, ib, "{what}: selected indices");
            for (i, (x, y)) in va.iter().zip(vb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: val {i}: {x} vs {y}");
            }
        }
        _ => panic!("{what}: reply kinds diverged"),
    }
}

/// Drive one payload through both coordinators and compare bitwise.
fn check(router: &Coordinator, single: &Coordinator, payload: Payload, opts: RequestOptions, what: &str) {
    let a = router
        .call_opts(payload.clone(), opts.clone(), TIMEOUT)
        .unwrap_or_else(|e| panic!("{what}: router: {e}"));
    let b = single
        .call_opts(payload, opts, TIMEOUT)
        .unwrap_or_else(|e| panic!("{what}: single: {e}"));
    assert_bitwise(&a, &b, what);
}

fn sampled_opts(k: usize, seed: u64) -> RequestOptions {
    RequestOptions {
        k: Some(k),
        temperature: 0.8,
        seed: Some(seed),
        ..RequestOptions::default()
    }
}

fn exercise(router: &Coordinator, single: &Coordinator, rng: &mut Xoshiro256pp, label: &str) {
    for i in 0..2 {
        let logits = rng.logits(VOCAB, 8.0);
        check(
            router,
            single,
            Payload::Softmax { logits },
            RequestOptions::default(),
            &format!("{label}: softmax {i}"),
        );
    }
    for i in 0..2 {
        let hidden = rng.logits(HIDDEN, 1.0);
        check(
            router,
            single,
            Payload::DecodeTopK { hidden },
            RequestOptions::with_k(5),
            &format!("{label}: decode {i}"),
        );
    }
    let hidden = rng.logits(HIDDEN, 1.0);
    check(
        router,
        single,
        Payload::DecodeTopK { hidden },
        sampled_opts(5, 0x5EED ^ rng.below(1 << 20)),
        &format!("{label}: sampled decode"),
    );
}

#[test]
fn router_matches_single_process_bitwise_across_backends_and_scheds() {
    let mut rng = Xoshiro256pp::seed_from_u64(0x40B7E4);
    for backend in ShardBackendKind::all() {
        for sched in [SchedPolicy::Fifo, SchedPolicy::Steal] {
            let cfg = base_cfg(backend, sched);
            let workers = spawn_workers(&cfg);
            let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
            let router = router_coord(&cfg, addrs);
            let single = Coordinator::start(&cfg).expect("single-process coordinator");
            let label = format!("{}/{:?}", backend.as_str(), sched);

            exercise(&router, &single, &mut rng, &label);

            router.shutdown();
            single.shutdown();
            for w in workers {
                w.halt();
            }
        }
    }
}

#[test]
fn router_property_random_batches_stay_bitwise_identical() {
    // Property flavor: many rounds of random payload batches through
    // one fixed topology; every reply must stay bitwise-equal to the
    // single-process reference, including in-flight-concurrent rounds.
    let cfg = base_cfg(ShardBackendKind::Auto, SchedPolicy::Steal);
    let workers = spawn_workers(&cfg);
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
    let router = router_coord(&cfg, addrs);
    let single = Coordinator::start(&cfg).expect("single-process coordinator");
    let mut rng = Xoshiro256pp::seed_from_u64(0x9409);

    for round in 0..6 {
        // Mixed batch, submitted concurrently so the router sees
        // multi-row frames, then compared reply-by-reply.
        let logits: Vec<Vec<f32>> = (0..3).map(|_| rng.logits(VOCAB, 6.0)).collect();
        let rx_r: Vec<_> = logits
            .iter()
            .map(|l| router.submit(Payload::Softmax { logits: l.clone() }).unwrap())
            .collect();
        let rx_s: Vec<_> = logits
            .iter()
            .map(|l| single.submit(Payload::Softmax { logits: l.clone() }).unwrap())
            .collect();
        for (i, (ra, rb)) in rx_r.into_iter().zip(rx_s).enumerate() {
            let a = ra.recv_timeout(TIMEOUT).unwrap().unwrap();
            let b = rb.recv_timeout(TIMEOUT).unwrap().unwrap();
            assert_bitwise(&a, &b, &format!("round {round}: batched softmax {i}"));
        }

        let k = 1 + (rng.below(8) as usize);
        let hidden = rng.logits(HIDDEN, 1.0);
        check(
            &router,
            &single,
            Payload::DecodeTopK { hidden },
            RequestOptions::with_k(k),
            &format!("round {round}: decode k={k}"),
        );
        let hidden = rng.logits(HIDDEN, 1.0);
        check(
            &router,
            &single,
            Payload::DecodeTopK { hidden },
            sampled_opts(k, rng.below(u32::MAX as u64)),
            &format!("round {round}: sampled k={k}"),
        );
    }
    router.shutdown();
    single.shutdown();
    for w in workers {
        w.halt();
    }
}

#[test]
fn router_requeues_dead_worker_slice_and_stays_bitwise() {
    // Worker 2 is a connection-refused address: every request whose
    // plan touches its slice must be requeued onto a healthy peer
    // (visible in `router.retry.requeued`) and still answer bitwise
    // identically — the plan never changes, only who computes it.
    let cfg = base_cfg(ShardBackendKind::Auto, SchedPolicy::Steal);
    let live: Vec<TestWorker> = ShardPlan::with_shards(VOCAB, WORKERS)
        .ranges()
        .take(2)
        .map(|r| TestWorker::spawn(cfg.clone(), (r.start, r.end)))
        .collect();
    let mut addrs: Vec<String> = live.iter().map(|w| w.addr.clone()).collect();
    addrs.push(dead_addr());
    let requeued = metrics::global().counter("router.retry.requeued");
    let before = requeued.get();

    let router = router_coord(&cfg, addrs);
    let single = Coordinator::start(&cfg).expect("single-process coordinator");
    let mut rng = Xoshiro256pp::seed_from_u64(0xDEAD);

    exercise(&router, &single, &mut rng, "dead-worker");

    assert!(
        requeued.get() > before,
        "a dead worker's shards must be requeued (router.retry.requeued {} -> {})",
        before,
        requeued.get()
    );
    router.shutdown();
    single.shutdown();
    for w in live {
        w.halt();
    }
}

#[test]
fn router_topology_surfaces_typed_errors_and_keeps_serving() {
    // An invalid request through the router topology must come back as
    // a typed rejection (here from the router coordinator's own
    // validation — the same surface a single-process server presents),
    // not a transport failure or a hang, and must not poison the
    // worker connections for the next request.
    let cfg = base_cfg(ShardBackendKind::Auto, SchedPolicy::Fifo);
    let workers = spawn_workers(&cfg);
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
    let router = router_coord(&cfg, addrs);

    let err = router
        .call_opts(
            Payload::DecodeTopK { hidden: vec![0.0; HIDDEN + 1] },
            RequestOptions::with_k(3),
            TIMEOUT,
        )
        .unwrap_err();
    assert!(err.to_string().contains("length"), "typed rejection expected, got: {err}");

    // The router keeps serving after the rejection.
    let mut rng = Xoshiro256pp::seed_from_u64(0x7E57);
    let logits = rng.logits(VOCAB, 5.0);
    assert!(router.call(Payload::Softmax { logits }, TIMEOUT).is_ok());

    router.shutdown();
    for w in workers {
        w.halt();
    }
}
