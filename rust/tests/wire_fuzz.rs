//! Wire-codec robustness: the decoder must return a structured error
//! for every malformed, truncated, type-confused, or wrong-version
//! frame — never panic, never accept garbage.
//!
//! Pure codec-level fuzzing (no sockets; the over-TCP rendition —
//! oversized frames, connection survival — lives in
//! `rust/tests/stream_e2e.rs`).  Deterministic: seeded Xoshiro, so a
//! failure reproduces.

use onlinesoftmax::coordinator::ErrorCode;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::wire;

/// A valid frame of every op, used as the mutation corpus.
fn corpus() -> Vec<String> {
    vec![
        r#"{"op":"softmax","logits":[1,2,3]}"#.to_string(),
        r#"{"op":"decode","hidden":[0.5,-0.25],"k":3}"#.to_string(),
        r#"{"op":"lm_step","session":7,"token":9,"k":5}"#.to_string(),
        r#"{"op":"open_session"}"#.to_string(),
        r#"{"op":"fork_session","session":1}"#.to_string(),
        r#"{"op":"close_session","session":1}"#.to_string(),
        r#"{"op":"stats"}"#.to_string(),
        r#"{"op":"ping"}"#.to_string(),
        r#"{"v":2,"op":"generate","session":4,"prompt":[3,9],"max_tokens":8,"k":5}"#.to_string(),
        r#"{"v":2,"op":"decode","hidden":[0.5],"priority":"batch","deadline_ms":250,"tag":"t"}"#
            .to_string(),
        // Router↔worker shard_scan frames, one per kind.
        r#"{"v":2,"op":"shard_scan","kind":"softmax","start":64,"end":96,"rows":[[1,2],[3,4]]}"#
            .to_string(),
        concat!(
            r#"{"v":2,"op":"shard_scan","kind":"decode","start":0,"end":4,"k":2,"#,
            r#""rows":[[0.5,1.5]],"samples":[{"seed":"18446744073709551615","temperature":0.8}]}"#
        )
        .to_string(),
        concat!(
            r#"{"v":2,"op":"shard_scan","kind":"scale","start":0,"end":2,"#,
            r#""rows":[[0.1,0.2]],"norms":[{"m":1.5,"d":2.0}]}"#
        )
        .to_string(),
    ]
}

/// A structurally valid `shard_scan` partials reply (the worker → router
/// direction), used as the reply-side mutation corpus.
fn partials_reply() -> String {
    concat!(
        r#"{"v":2,"ok":true,"partials":[{"m":1.5,"d":2.0,"#,
        r#""topk":{"vals":[0.9,0.5],"idx":[65,64]}}]}"#
    )
    .to_string()
}

#[test]
fn corpus_decodes_cleanly() {
    for frame in corpus() {
        wire::decode_request(&frame).unwrap_or_else(|e| panic!("{frame}: {}", e.error));
    }
}

#[test]
fn every_truncation_errors_without_panicking() {
    for frame in corpus() {
        for cut in 0..frame.len() {
            let truncated = &frame[..cut];
            if let Err(e) = wire::decode_request(truncated) {
                assert!(
                    !e.error.message.is_empty(),
                    "truncation of `{frame}` at {cut}: empty error message"
                );
            }
            // A prefix that happens to parse is fine; the contract is
            // "no panic, no hang" — and any Err is structured.
        }
    }
}

#[test]
fn random_bytes_never_panic_the_decoder() {
    let mut rng = Xoshiro256pp::seed_from_u64(0xF00D);
    for _ in 0..2_000 {
        let len = (rng.below(256) + 1) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(256)) as u8).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = wire::decode_request(&line);
        let _ = wire::decode_response(&line);
        let _ = wire::decode_stream_event(&line);
    }
}

#[test]
fn random_json_shaped_mutations_error_structurally() {
    // Mutate valid frames: splice random printable bytes into random
    // positions.  Whatever happens, an Err must carry a ServeError.
    let mut rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let corpus = corpus();
    for _ in 0..2_000 {
        let base = &corpus[rng.below(corpus.len() as u64) as usize];
        let mut s = base.clone().into_bytes();
        let splices = 1 + rng.below(4);
        for _ in 0..splices {
            let pos = rng.below(s.len() as u64 + 1) as usize;
            let b = b' ' + (rng.below(95)) as u8; // printable ascii
            s.insert(pos, b);
        }
        let line = String::from_utf8_lossy(&s).into_owned();
        if let Err(e) = wire::decode_request(&line) {
            assert!(ErrorCode::parse(e.error.code.as_str()).is_some());
            assert!(e.v == 1 || e.v == 2, "error version is renderable: {}", e.v);
        }
    }
}

#[test]
fn v1_k_stays_lenient_v2_k_is_strict() {
    // The frozen v1 surface tolerates ill-typed `k` (falls back to the
    // server default, as the legacy decoder did); v2 rejects it.
    let f = wire::decode_request(r#"{"op":"decode","hidden":[0.5],"k":"five"}"#).unwrap();
    assert_eq!(f.options.k, None, "v1 ill-typed k falls back to default");
    let f = wire::decode_request(r#"{"op":"decode","hidden":[0.5],"k":-1}"#).unwrap();
    assert_eq!(f.options.k, None);
    let e = wire::decode_request(r#"{"v":2,"op":"decode","hidden":[0.5],"k":"five"}"#)
        .unwrap_err();
    assert_eq!(e.error.code, ErrorCode::BadRequest);
}

#[test]
fn wrong_versions_are_rejected_typed() {
    for v in ["0", "3", "-1", "99", "1.5", "\"2\"", "null", "[2]", "{}"] {
        let line = format!(r#"{{"v":{v},"op":"ping"}}"#);
        let e = wire::decode_request(&line).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest, "v={v}: {}", e.error);
    }
    // explicit v1/v2 still fine
    assert_eq!(wire::decode_request(r#"{"v":1,"op":"ping"}"#).unwrap().v, 1);
    assert_eq!(wire::decode_request(r#"{"v":2,"op":"ping"}"#).unwrap().v, 2);
}

#[test]
fn type_confused_fields_are_rejected_typed() {
    let cases = [
        r#"{"op":"softmax","logits":"not an array"}"#,
        r#"{"op":"softmax","logits":[1,"x"]}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"k":"five"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"k":-1}"#,
        r#"{"op":"lm_step","session":-4,"token":1}"#,
        r#"{"op":"lm_step","session":1,"token":99999999999999}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"priority":"urgent"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"deadline_ms":"soon"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"tag":7}"#,
        r#"{"v":2,"op":"generate","session":1,"prompt":"abc","max_tokens":2}"#,
        r#"{"v":2,"op":"generate","session":1,"prompt":[1],"max_tokens":-2}"#,
        r#"{"op":7}"#,
        r#"[1,2,3]"#,
        r#""just a string""#,
    ];
    for line in cases {
        let e = wire::decode_request(line).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest, "{line}: {}", e.error);
        assert!(!e.error.message.is_empty());
    }
}

#[test]
fn shard_scan_version_confusion_is_rejected_typed() {
    // shard_scan is v2-only: a v1 or unversioned frame must be refused
    // with a structured error, not silently decoded under v1 leniency.
    let body = r#""op":"shard_scan","kind":"softmax","start":0,"end":2,"rows":[[1,2]]"#;
    for prefix in ["", r#""v":1,"#, r#""v":3,"#, r#""v":"2","#] {
        let line = format!("{{{prefix}{body}}}");
        let e = wire::decode_request(&line).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest, "{line}: {}", e.error);
        assert!(!e.error.message.is_empty());
    }
    let ok = format!(r#"{{"v":2,{body}}}"#);
    wire::decode_request(&ok).unwrap_or_else(|e| panic!("{ok}: {}", e.error));
}

/// Parse + decode a partials reply; `Err` for either stage.  The test
/// contract is only "no panic, typed refusal".
fn try_partials(line: &str, rows: usize, k: usize, start: usize, end: usize) -> bool {
    match onlinesoftmax::json::parse(line) {
        Ok(v) => wire::decode_shard_partials(&v, rows, k, start, end, &vec![false; rows]).is_ok(),
        Err(_) => false,
    }
}

#[test]
fn partials_reply_corpus_decodes_then_every_truncation_errors() {
    let reply = partials_reply();
    assert!(try_partials(&reply, 1, 2, 64, 96), "corpus reply must decode");
    for cut in 0..reply.len() {
        // Any prefix either fails to parse or fails partial validation
        // (it can never resurface as a *valid* one-row reply) — and
        // neither stage may panic.
        assert!(
            !try_partials(&reply[..cut], 1, 2, 64, 96),
            "truncation at {cut} decoded as a valid reply"
        );
    }
}

#[test]
fn hostile_partials_are_rejected_not_merged() {
    // A corrupt or hostile worker must never inject a poisoned partial
    // into the router's ⊕ tree: non-finite normalizers, out-of-range
    // global indices, misaligned or oversized buffers all refuse.
    let cases = [
        // non-finite / ill-typed (m, d)
        r#"{"partials":[{"m":null,"d":2.0,"topk":{"vals":[],"idx":[]}}]}"#,
        r#"{"partials":[{"m":"nan","d":2.0,"topk":{"vals":[],"idx":[]}}]}"#,
        r#"{"partials":[{"m":1e999,"d":2.0,"topk":{"vals":[],"idx":[]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":0.0,"topk":{"vals":[],"idx":[]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":-3.0,"topk":{"vals":[],"idx":[]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":1e999,"topk":{"vals":[],"idx":[]}}]}"#,
        // global indices outside the declared [64, 96) shard range
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[0.9],"idx":[63]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[0.9],"idx":[96]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[0.9],"idx":[-1]}}]}"#,
        // misaligned / oversized top-k buffers
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[0.9,0.5],"idx":[64]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[1,2,3],"idx":[64,65,66]}}]}"#,
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[0.9],"idx":[64.5]}}]}"#,
        // structural confusion
        r#"{"partials":[{"m":1.0,"d":1.0}]}"#,
        r#"{"partials":[null]}"#,
        r#"{"partials":{}}"#,
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]}},{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]}}]}"#,
        // sampled state on a greedy query
        r#"{"partials":[{"m":1.0,"d":1.0,"topk":{"vals":[],"idx":[]},"sampled":{"s":[],"x":[],"p":[]}}]}"#,
        r#"{}"#,
    ];
    for line in cases {
        assert!(!try_partials(line, 1, 2, 64, 96), "accepted hostile reply: {line}");
    }
}

#[test]
fn hostile_norms_and_slices_are_rejected_not_merged() {
    let bad_norms = [
        r#"{"norms":[{"m":1e999,"d":1.0}]}"#,
        r#"{"norms":[{"m":1.0,"d":0.0}]}"#,
        r#"{"norms":[{"m":1.0,"d":null}]}"#,
        r#"{"norms":[{"m":1.0,"d":1.0},{"m":1.0,"d":1.0}]}"#, // row-count mismatch
        r#"{"norms":"x"}"#,
        r#"{}"#,
    ];
    for line in bad_norms {
        let ok = onlinesoftmax::json::parse(line)
            .map(|v| wire::decode_shard_norms(&v, 1).is_ok())
            .unwrap_or(false);
        assert!(!ok, "accepted hostile norms reply: {line}");
    }
    let bad_slices = [
        r#"{"slices":[[0.1,0.2,0.3]]}"#,   // width 3, expected 2
        r#"{"slices":[[0.1,1e999]]}"#,     // non-finite probability
        r#"{"slices":[[0.1,null]]}"#,      // ill-typed element
        r#"{"slices":[[0.1,0.2],[0.3,0.4]]}"#, // row-count mismatch
        r#"{"slices":7}"#,
        r#"{}"#,
    ];
    for line in bad_slices {
        let ok = onlinesoftmax::json::parse(line)
            .map(|v| wire::decode_shard_slices(&v, 1, 2).is_ok())
            .unwrap_or(false);
        assert!(!ok, "accepted hostile slices reply: {line}");
    }
}

#[test]
fn random_mutations_of_shard_frames_never_panic() {
    // Byte-splice fuzz over both shard_scan directions: the request
    // decoder and all three reply decoders must refuse or accept
    // structurally — never panic.
    let mut rng = Xoshiro256pp::seed_from_u64(0x5CA2);
    let corpus: Vec<String> = corpus()
        .into_iter()
        .filter(|f| f.contains("shard_scan"))
        .chain(std::iter::once(partials_reply()))
        .collect();
    assert!(corpus.len() == 4, "three shard_scan kinds + one reply");
    for _ in 0..2_000 {
        let base = &corpus[rng.below(corpus.len() as u64) as usize];
        let mut s = base.clone().into_bytes();
        for _ in 0..(1 + rng.below(4)) {
            let pos = rng.below(s.len() as u64 + 1) as usize;
            s.insert(pos, b' ' + (rng.below(95)) as u8);
        }
        let line = String::from_utf8_lossy(&s).into_owned();
        if let Err(e) = wire::decode_request(&line) {
            assert!(ErrorCode::parse(e.error.code.as_str()).is_some());
        }
        if let Ok(v) = onlinesoftmax::json::parse(&line) {
            let _ = wire::decode_shard_partials(&v, 1, 2, 64, 96, &[false]);
            let _ = wire::decode_shard_norms(&v, 1);
            let _ = wire::decode_shard_slices(&v, 1, 2);
        }
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    // The recursive-descent parser should error out on malformed deep
    // nesting rather than crash; depth is bounded by the input size we
    // hand it.
    let deep = format!("{}1{}", "[".repeat(1_000), "]".repeat(1_000));
    let _ = wire::decode_request(&deep);
    let open_only = "[".repeat(2_000);
    assert!(wire::decode_request(&open_only).is_err());
}
