//! Wire-codec robustness: the decoder must return a structured error
//! for every malformed, truncated, type-confused, or wrong-version
//! frame — never panic, never accept garbage.
//!
//! Pure codec-level fuzzing (no sockets; the over-TCP rendition —
//! oversized frames, connection survival — lives in
//! `rust/tests/stream_e2e.rs`).  Deterministic: seeded Xoshiro, so a
//! failure reproduces.

use onlinesoftmax::coordinator::ErrorCode;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::wire;

/// A valid frame of every op, used as the mutation corpus.
fn corpus() -> Vec<String> {
    vec![
        r#"{"op":"softmax","logits":[1,2,3]}"#.to_string(),
        r#"{"op":"decode","hidden":[0.5,-0.25],"k":3}"#.to_string(),
        r#"{"op":"lm_step","session":7,"token":9,"k":5}"#.to_string(),
        r#"{"op":"open_session"}"#.to_string(),
        r#"{"op":"fork_session","session":1}"#.to_string(),
        r#"{"op":"close_session","session":1}"#.to_string(),
        r#"{"op":"stats"}"#.to_string(),
        r#"{"op":"ping"}"#.to_string(),
        r#"{"v":2,"op":"generate","session":4,"prompt":[3,9],"max_tokens":8,"k":5}"#.to_string(),
        r#"{"v":2,"op":"decode","hidden":[0.5],"priority":"batch","deadline_ms":250,"tag":"t"}"#
            .to_string(),
    ]
}

#[test]
fn corpus_decodes_cleanly() {
    for frame in corpus() {
        wire::decode_request(&frame).unwrap_or_else(|e| panic!("{frame}: {}", e.error));
    }
}

#[test]
fn every_truncation_errors_without_panicking() {
    for frame in corpus() {
        for cut in 0..frame.len() {
            let truncated = &frame[..cut];
            if let Err(e) = wire::decode_request(truncated) {
                assert!(
                    !e.error.message.is_empty(),
                    "truncation of `{frame}` at {cut}: empty error message"
                );
            }
            // A prefix that happens to parse is fine; the contract is
            // "no panic, no hang" — and any Err is structured.
        }
    }
}

#[test]
fn random_bytes_never_panic_the_decoder() {
    let mut rng = Xoshiro256pp::seed_from_u64(0xF00D);
    for _ in 0..2_000 {
        let len = (rng.below(256) + 1) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(256)) as u8).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = wire::decode_request(&line);
        let _ = wire::decode_response(&line);
        let _ = wire::decode_stream_event(&line);
    }
}

#[test]
fn random_json_shaped_mutations_error_structurally() {
    // Mutate valid frames: splice random printable bytes into random
    // positions.  Whatever happens, an Err must carry a ServeError.
    let mut rng = Xoshiro256pp::seed_from_u64(0xBEEF);
    let corpus = corpus();
    for _ in 0..2_000 {
        let base = &corpus[rng.below(corpus.len() as u64) as usize];
        let mut s = base.clone().into_bytes();
        let splices = 1 + rng.below(4);
        for _ in 0..splices {
            let pos = rng.below(s.len() as u64 + 1) as usize;
            let b = b' ' + (rng.below(95)) as u8; // printable ascii
            s.insert(pos, b);
        }
        let line = String::from_utf8_lossy(&s).into_owned();
        if let Err(e) = wire::decode_request(&line) {
            assert!(ErrorCode::parse(e.error.code.as_str()).is_some());
            assert!(e.v == 1 || e.v == 2, "error version is renderable: {}", e.v);
        }
    }
}

#[test]
fn v1_k_stays_lenient_v2_k_is_strict() {
    // The frozen v1 surface tolerates ill-typed `k` (falls back to the
    // server default, as the legacy decoder did); v2 rejects it.
    let f = wire::decode_request(r#"{"op":"decode","hidden":[0.5],"k":"five"}"#).unwrap();
    assert_eq!(f.options.k, None, "v1 ill-typed k falls back to default");
    let f = wire::decode_request(r#"{"op":"decode","hidden":[0.5],"k":-1}"#).unwrap();
    assert_eq!(f.options.k, None);
    let e = wire::decode_request(r#"{"v":2,"op":"decode","hidden":[0.5],"k":"five"}"#)
        .unwrap_err();
    assert_eq!(e.error.code, ErrorCode::BadRequest);
}

#[test]
fn wrong_versions_are_rejected_typed() {
    for v in ["0", "3", "-1", "99", "1.5", "\"2\"", "null", "[2]", "{}"] {
        let line = format!(r#"{{"v":{v},"op":"ping"}}"#);
        let e = wire::decode_request(&line).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest, "v={v}: {}", e.error);
    }
    // explicit v1/v2 still fine
    assert_eq!(wire::decode_request(r#"{"v":1,"op":"ping"}"#).unwrap().v, 1);
    assert_eq!(wire::decode_request(r#"{"v":2,"op":"ping"}"#).unwrap().v, 2);
}

#[test]
fn type_confused_fields_are_rejected_typed() {
    let cases = [
        r#"{"op":"softmax","logits":"not an array"}"#,
        r#"{"op":"softmax","logits":[1,"x"]}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"k":"five"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"k":-1}"#,
        r#"{"op":"lm_step","session":-4,"token":1}"#,
        r#"{"op":"lm_step","session":1,"token":99999999999999}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"priority":"urgent"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"deadline_ms":"soon"}"#,
        r#"{"v":2,"op":"decode","hidden":[0.5],"tag":7}"#,
        r#"{"v":2,"op":"generate","session":1,"prompt":"abc","max_tokens":2}"#,
        r#"{"v":2,"op":"generate","session":1,"prompt":[1],"max_tokens":-2}"#,
        r#"{"op":7}"#,
        r#"[1,2,3]"#,
        r#""just a string""#,
    ];
    for line in cases {
        let e = wire::decode_request(line).unwrap_err();
        assert_eq!(e.error.code, ErrorCode::BadRequest, "{line}: {}", e.error);
        assert!(!e.error.message.is_empty());
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    // The recursive-descent parser should error out on malformed deep
    // nesting rather than crash; depth is bounded by the input size we
    // hand it.
    let deep = format!("{}1{}", "[".repeat(1_000), "]".repeat(1_000));
    let _ = wire::decode_request(&deep);
    let open_only = "[".repeat(2_000);
    assert!(wire::decode_request(&open_only).is_err());
}
