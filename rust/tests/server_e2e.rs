//! End-to-end over TCP: server + wire protocol + client.

use std::sync::Arc;
use std::time::Duration;

use onlinesoftmax::config::{ServeConfig, ServingMode};
use onlinesoftmax::coordinator::Coordinator;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::{client::Client, Server};
use onlinesoftmax::softmax::scalar;

fn artifacts_ready() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

struct Running {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn start_server() -> Running {
    let mut cfg = ServeConfig::default();
    cfg.artifacts_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.mode = ServingMode::Online;
    cfg.max_wait = Duration::from_micros(500);
    cfg.addr = "127.0.0.1:0".into();
    let coordinator = Arc::new(Coordinator::start(&cfg).unwrap());
    let server = Server::bind(&cfg.addr, coordinator, 8).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let thread = std::thread::spawn(move || {
        let _ = server.serve();
    });
    Running { addr, stop, thread: Some(thread) }
}

#[test]
fn full_protocol_over_tcp() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();

    // ping + stats
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.get("metrics").is_some());

    // softmax
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let logits = rng.logits(8192, 6.0);
    let probs = client.softmax(&logits).unwrap();
    let mut want = vec![0.0; logits.len()];
    scalar::safe(&logits, &mut want);
    assert_eq!(probs.len(), want.len());
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");

    // decode
    let hidden = rng.logits(128, 1.0);
    let (vals, idx) = client.decode(&hidden, Some(5)).unwrap();
    assert_eq!(vals.len(), 5);
    assert_eq!(idx.len(), 5);
    assert!(vals.windows(2).all(|w| w[0] >= w[1]));

    // sessions
    let sid = client.open_session().unwrap();
    let (v1, _) = client.lm_step(sid, 4, Some(3)).unwrap();
    assert_eq!(v1.len(), 3);
    client.close_session(sid).unwrap();
    let err = client.lm_step(sid, 4, Some(3)).unwrap_err();
    assert!(format!("{err}").contains("unknown session"), "{err}");

    // malformed input is an error, not a hang/disconnect
    let bad = client.softmax(&[1.0, 2.0]);
    assert!(bad.is_err());
    client.ping().unwrap(); // connection still alive
}

#[test]
fn concurrent_clients_are_batched_together() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let server = start_server();
    let addr = server.addr.clone();
    let n_clients = 8;
    let correct: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let mut rng = Xoshiro256pp::seed_from_u64(100 + i);
                    let hidden = rng.logits(128, 1.0);
                    let (vals, idx) = client.decode(&hidden, Some(5)).unwrap();
                    vals.len() == 5 && idx.iter().all(|&x| x >= 0 && (x as usize) < 8192)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(correct.iter().all(|&c| c));
}
