//! Coordinator integration: the full L3 stack (batcher → executor →
//! PJRT engines → ⊕ merge) against real artifacts.

use std::time::Duration;

use onlinesoftmax::config::{ServeConfig, ServingMode};
use onlinesoftmax::coordinator::{beam, Coordinator, Payload, Reply, RequestOptions};
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::softmax::{fused, scalar};

fn artifacts_ready() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

fn config(mode: ServingMode, shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.artifacts_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.mode = mode;
    cfg.shards = shards;
    cfg.workers = 2;
    cfg.max_wait = Duration::from_micros(500);
    cfg
}

const TIMEOUT: Duration = Duration::from_secs(60);

fn close(a: f32, b: f32, rtol: f32) -> bool {
    (a - b).abs() <= 1e-7 + rtol * a.abs().max(b.abs())
}

#[test]
fn softmax_request_matches_rust_reference() {
    require_artifacts!();
    let coord = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let logits = rng.logits(vocab, 8.0);
    match coord.call(Payload::Softmax { logits: logits.clone() }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => {
            let mut want = vec![0.0; vocab];
            scalar::safe(&logits, &mut want);
            assert_eq!(probs.len(), vocab);
            for (i, (a, b)) in probs.iter().zip(&want).enumerate() {
                assert!(close(*a, *b, 1e-4), "idx {i}: {a} vs {b}");
            }
        }
        other => panic!("unexpected {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn sharded_softmax_equals_unsharded() {
    require_artifacts!();
    let coord1 = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let coord4 = Coordinator::start(&config(ServingMode::Online, 4)).unwrap();
    let vocab = coord1.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let logits = rng.logits(vocab, 10.0);
    let p1 = match coord1.call(Payload::Softmax { logits: logits.clone() }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => probs,
        other => panic!("{other:?}"),
    };
    let p4 = match coord4.call(Payload::Softmax { logits }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => probs,
        other => panic!("{other:?}"),
    };
    for (i, (a, b)) in p1.iter().zip(&p4).enumerate() {
        assert!(close(*a, *b, 1e-4), "idx {i}: {a} vs {b}");
    }
    coord1.shutdown();
    coord4.shutdown();
}

#[test]
fn decode_safe_online_and_sharded_all_agree() {
    require_artifacts!();
    let modes = [
        config(ServingMode::Safe, 1),
        config(ServingMode::Online, 1),
        config(ServingMode::Online, 4),
    ];
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let mut results: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
    let hidden_len = 128;
    let hidden = rng.logits(hidden_len, 1.0);
    for cfg in &modes {
        let coord = Coordinator::start(cfg).unwrap();
        assert_eq!(coord.executor().hidden(), hidden_len);
        match coord
            .call_opts(
                Payload::DecodeTopK { hidden: hidden.clone() },
                RequestOptions::with_k(5),
                TIMEOUT,
            )
            .unwrap()
        {
            Reply::TopK { vals, idx } => results.push((vals, idx)),
            other => panic!("{other:?}"),
        }
        coord.shutdown();
    }
    for r in &results[1..] {
        assert_eq!(r.1, results[0].1, "indices agree across modes");
        for (a, b) in r.0.iter().zip(&results[0].0) {
            assert!(close(*a, *b, 1e-3), "{a} vs {b}");
        }
    }
    // cross-check against host-side reference
    let coord = Coordinator::start(&modes[0]).unwrap();
    let logits = coord.executor().model().project_row(&hidden);
    let (want_vals, want_idx) = fused::online_topk(&logits, 5);
    assert_eq!(results[0].1, want_idx);
    for (a, b) in results[0].0.iter().zip(&want_vals) {
        assert!(close(*a, *b, 1e-3), "{a} vs {b}");
    }
    coord.shutdown();
}

#[test]
fn batched_requests_get_individual_answers() {
    require_artifacts!();
    let mut cfg = config(ServingMode::Online, 1);
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(20); // force batching window
    let coord = Coordinator::start(&cfg).unwrap();
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let inputs: Vec<Vec<f32>> = (0..6).map(|_| rng.logits(vocab, 5.0)).collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|l| coord.submit(Payload::Softmax { logits: l.clone() }).unwrap())
        .collect();
    for (input, rx) in inputs.iter().zip(rxs) {
        match rx.recv_timeout(TIMEOUT).unwrap().unwrap() {
            Reply::Softmax { probs } => {
                let mut want = vec![0.0; vocab];
                scalar::safe(input, &mut want);
                let max_i =
                    probs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
                let want_i =
                    want.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
                assert_eq!(max_i, want_i, "each request got its own answer");
            }
            other => panic!("{other:?}"),
        }
    }
    coord.shutdown();
}

#[test]
fn per_request_errors_do_not_poison_batch() {
    require_artifacts!();
    let coord = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let good = coord.submit(Payload::Softmax { logits: rng.logits(vocab, 3.0) }).unwrap();
    let bad = coord.submit(Payload::Softmax { logits: vec![1.0; 3] }).unwrap();
    assert!(good.recv_timeout(TIMEOUT).unwrap().is_ok());
    let err = bad.recv_timeout(TIMEOUT).unwrap().unwrap_err();
    assert!(err.to_string().contains("length"), "{err}");
    coord.shutdown();
}

#[test]
fn lm_sessions_step_deterministically() {
    require_artifacts!();
    let coord = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let s1 = coord.open_session();
    let s2 = coord.open_session();
    let r1 = coord
        .call_opts(Payload::LmStep { session: s1, token: 17 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    let r2 = coord
        .call_opts(Payload::LmStep { session: s2, token: 17 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    assert_eq!(r1, r2, "same token from same initial state → same distribution");
    // diverge the sessions
    let r1b = coord
        .call_opts(Payload::LmStep { session: s1, token: 3 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    let r2b = coord
        .call_opts(Payload::LmStep { session: s2, token: 9 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    assert_ne!(r1b, r2b, "different tokens diverge the state");
    // unknown session errors
    let err = coord
        .call(Payload::LmStep { session: 999_999, token: 0 }, TIMEOUT)
        .unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");
    coord.shutdown();
}

#[test]
fn beam_search_runs_and_is_deterministic() {
    require_artifacts!();
    let coord = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let cfg = beam::BeamConfig { width: 3, steps: 4, k: 5, timeout: TIMEOUT };
    let beam1 = beam::beam_search(&coord, cfg, 7).unwrap();
    let tokens1: Vec<Vec<i32>> = beam1.iter().map(|h| h.tokens.clone()).collect();
    beam::release(&coord, &beam1);
    let beam2 = beam::beam_search(&coord, cfg, 7).unwrap();
    let tokens2: Vec<Vec<i32>> = beam2.iter().map(|h| h.tokens.clone()).collect();
    beam::release(&coord, &beam2);
    assert_eq!(tokens1, tokens2, "beam search is deterministic");
    assert_eq!(tokens1.len(), 3);
    assert!(tokens1.iter().all(|t| t.len() == 5), "start + 4 steps");
    // hypotheses sorted by logprob
    for w in beam1.windows(2) {
        assert!(w[0].logprob >= w[1].logprob);
    }
    coord.shutdown();
}

#[test]
fn invalid_k_rejected() {
    require_artifacts!();
    let coord = Coordinator::start(&config(ServingMode::Online, 1)).unwrap();
    let hidden = vec![0.0; coord.executor().hidden()];
    let err = coord
        .call_opts(Payload::DecodeTopK { hidden }, RequestOptions::with_k(100), TIMEOUT)
        .unwrap_err();
    assert!(err.to_string().contains("k="), "{err}");
    coord.shutdown();
}
