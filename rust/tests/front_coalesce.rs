//! Coordinator-level properties of the coalescing/caching front:
//! coalesced and cached replies are **bitwise identical** to a plain
//! execution, requests differing only in `tag`/`deadline_ms` share one
//! execution, and requests differing in `k` or priority never do.
//!
//! Determinism trick: with a long `max_wait` and nothing else queued, a
//! submitted leader sits in the batcher for the whole flush window, so
//! identical follow-up submits are *guaranteed* to find it in flight
//! and coalesce — no racy sleeps needed.

use std::time::Duration;

use onlinesoftmax::config::{BackendKind, ServeConfig};
use onlinesoftmax::coordinator::{
    Coordinator, Payload, Priority, Reply, RequestOptions,
};
use onlinesoftmax::rng::Xoshiro256pp;

const TIMEOUT: Duration = Duration::from_secs(60);

/// Small host backend, single-thread kernels (vocab below the shard
/// threshold), and a wide flush window so queued leaders linger.
fn front_config(cache_capacity: usize, coalesce: bool) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.vocab = 512;
    cfg.hidden = 32;
    cfg.workers = 2;
    cfg.max_wait = Duration::from_millis(40);
    cfg.cache_capacity = cache_capacity;
    cfg.cache_coalesce = coalesce;
    cfg
}

/// Exact-bits fingerprint of a reply — `f32::to_bits` so "equal"
/// means bitwise equal, not approximately equal.
fn bits(reply: &Reply) -> (Vec<u32>, Vec<i64>) {
    match reply {
        Reply::Softmax { probs } => (probs.iter().map(|p| p.to_bits()).collect(), Vec::new()),
        Reply::TopK { vals, idx } => {
            (vals.iter().map(|v| v.to_bits()).collect(), idx.clone())
        }
    }
}

fn recv(rx: onlinesoftmax::exec::channel::OnceReceiver<
    Result<Reply, onlinesoftmax::coordinator::ServeError>,
>) -> Reply {
    rx.recv_timeout(TIMEOUT).expect("reply channel").expect("ok reply")
}

#[test]
fn coalesced_and_cached_replies_are_bitwise_identical_to_plain_execution() {
    let fronted = Coordinator::start(&front_config(256, true)).unwrap();
    let plain = Coordinator::start(&front_config(0, false)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(11);

    for round in 0..4 {
        let payload = if round % 2 == 0 {
            Payload::Softmax { logits: rng.logits(512, 6.0) }
        } else {
            Payload::DecodeTopK { hidden: rng.logits(32, 1.0) }
        };
        // Leader + 3 followers submitted back-to-back: the leader is
        // still waiting out `max_wait`, so the followers coalesce.
        let rxs: Vec<_> = (0..4)
            .map(|_| fronted.submit(payload.clone()).unwrap())
            .collect();
        let replies: Vec<_> = rxs.into_iter().map(recv).collect();
        // A later identical submit is answered from the cache.
        let cached = recv(fronted.submit(payload.clone()).unwrap());
        // The reference execution has no front at all.
        let reference = recv(plain.submit(payload).unwrap());

        let want = bits(&reference);
        for (i, r) in replies.iter().chain(std::iter::once(&cached)).enumerate() {
            assert_eq!(bits(r), want, "round {round} reply {i} drifted from plain bits");
        }
    }

    let stats = fronted.cache_stats();
    assert_eq!(stats.misses, 4, "one execution per distinct payload");
    assert_eq!(stats.coalesced, 12, "three followers per round");
    assert_eq!(stats.hits, 4, "one cache hit per round");
    assert_eq!(stats.entries, 4);
    assert_eq!(plain.cache_stats(), Default::default(), "plain front counts nothing");

    fronted.shutdown();
    plain.shutdown();
}

#[test]
fn tag_and_deadline_differences_coalesce_but_k_and_priority_never_do() {
    let coord = Coordinator::start(&front_config(256, true)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(12);
    let hidden = rng.logits(32, 1.0);
    let payload = Payload::DecodeTopK { hidden };

    let leader_opts = RequestOptions {
        client_tag: Some("leader".into()),
        ..RequestOptions::default()
    };
    // Same key: differs only in tag + deadline.
    let follower_opts = RequestOptions {
        client_tag: Some("follower".into()),
        deadline: Some(Duration::from_secs(30)),
        ..RequestOptions::default()
    };
    // `k = Some(default_k)` resolves to the same key as `k = None`.
    let explicit_default_k = RequestOptions::with_k(5);
    // Different keys: an explicit non-default k, and a batch-priority twin.
    let other_k = RequestOptions::with_k(7);
    let batch_priority = RequestOptions {
        priority: Priority::Batch,
        ..RequestOptions::default()
    };

    let rx_leader = coord.submit_opts(payload.clone(), leader_opts).unwrap();
    let rx_follow = coord.submit_opts(payload.clone(), follower_opts).unwrap();
    let rx_same_k = coord.submit_opts(payload.clone(), explicit_default_k).unwrap();
    let rx_other_k = coord.submit_opts(payload.clone(), other_k).unwrap();
    let rx_batch = coord.submit_opts(payload.clone(), batch_priority).unwrap();

    let stats = coord.cache_stats();
    assert_eq!(stats.coalesced, 2, "tag/deadline-only and default-k twins coalesce");
    assert_eq!(stats.misses, 3, "leader, k=7, and batch-priority each execute");

    let leader = recv(rx_leader);
    assert_eq!(bits(&recv(rx_follow)), bits(&leader), "follower shares leader bits");
    assert_eq!(bits(&recv(rx_same_k)), bits(&leader), "explicit default k too");
    let other = recv(rx_other_k);
    match (&leader, &other) {
        (Reply::TopK { vals: a, .. }, Reply::TopK { vals: b, .. }) => {
            assert_eq!(a.len(), 5);
            assert_eq!(b.len(), 7, "k=7 ran its own execution");
        }
        other => panic!("unexpected replies {other:?}"),
    }
    // Same payload + k, different priority: separate execution, but
    // deterministic kernels mean identical bits — which is exactly why
    // the key must split on priority (scheduling class), not results.
    assert_eq!(bits(&recv(rx_batch)), bits(&leader));

    coord.shutdown();
}

#[test]
fn coalesced_errors_share_fate_but_are_not_cached() {
    let coord = Coordinator::start(&front_config(256, true)).unwrap();
    // Wrong vector length → executor rejects with invalid_argument.
    let payload = Payload::Softmax { logits: vec![1.0, 2.0, 3.0] };
    let rx1 = coord.submit(payload.clone()).unwrap();
    let rx2 = coord.submit(payload.clone()).unwrap();
    assert_eq!(coord.cache_stats().coalesced, 1);

    let e1 = rx1.recv_timeout(TIMEOUT).unwrap().unwrap_err();
    let e2 = rx2.recv_timeout(TIMEOUT).unwrap().unwrap_err();
    assert_eq!(e1, e2, "followers share the leader's typed error");

    // Errors never enter the cache: a retry executes again (miss), it
    // is not replayed from a poisoned entry.
    let rx3 = coord.submit(payload).unwrap();
    assert!(rx3.recv_timeout(TIMEOUT).unwrap().is_err());
    let stats = coord.cache_stats();
    assert_eq!(stats.hits, 0);
    assert_eq!(stats.misses, 2);
    assert_eq!(stats.entries, 0);

    coord.shutdown();
}
