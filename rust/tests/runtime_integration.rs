//! Integration: the PJRT runtime executes real AOT artifacts and the
//! numerics agree with the rust softmax implementations — closing the
//! L1/L2 (python, build-time) ↔ L3 (rust, run-time) loop.
//!
//! Requires `make artifacts`; every test skips gracefully when the
//! artifacts directory is absent so `cargo test` works pre-AOT.

use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::runtime::{Engine, EnginePool, Input, Manifest, Tensor};
use onlinesoftmax::softmax::{self, Algorithm};
use onlinesoftmax::topk;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn assert_close(a: &[f32], b: &[f32], rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-7 + rtol * x.abs().max(y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn softmax_safe_artifact_matches_rust() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let entry = engine.manifest().bucket_for("softmax_safe", 4).unwrap();
    let (b, v) = (entry.batch, entry.vocab);
    let name = entry.name.clone();

    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let x = rng.logits(b * v, 8.0);
    let out = engine
        .execute(&name, vec![Tensor::f32(vec![b, v], x.clone()).unwrap()])
        .unwrap();
    let y = out[0].as_f32().unwrap();

    let mut expected = vec![0.0; b * v];
    softmax::compute_batch(&x, v, Algorithm::Safe, &mut expected);
    assert_close(y, &expected, 1e-4, "softmax_safe");
    engine.shutdown();
}

#[test]
fn decode_topk_artifacts_agree_with_each_other_and_rust() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let safe_e = engine.manifest().bucket_for("decode_topk_safe", 1).unwrap().clone();
    let online_e = engine.manifest().bucket_for("decode_topk_online", 1).unwrap().clone();
    let (b, h, v) = (safe_e.batch, safe_e.hidden.unwrap(), safe_e.vocab);
    let k = safe_e.k.unwrap();

    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let hvec = rng.logits(b * h, 1.0);
    let wvec = rng.logits(v * h, 0.2);
    let inputs = || {
        vec![
            Tensor::f32(vec![b, h], hvec.clone()).unwrap(),
            Tensor::f32(vec![v, h], wvec.clone()).unwrap(),
        ]
    };
    let out_safe = engine.execute(&safe_e.name, inputs()).unwrap();
    let out_online = engine.execute(&online_e.name, inputs()).unwrap();

    // the two serving variants must agree with each other
    assert_close(
        out_safe[0].as_f32().unwrap(),
        out_online[0].as_f32().unwrap(),
        1e-4,
        "safe vs online vals",
    );
    assert_eq!(out_safe[1].as_i32().unwrap(), out_online[1].as_i32().unwrap());

    // ... and with the rust implementation of projection + Alg 4
    for row in 0..b {
        let mut logits = vec![0.0f32; v];
        for (j, l) in logits.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for d in 0..h {
                acc += hvec[row * h + d] * wvec[j * h + d];
            }
            *l = acc;
        }
        let (vals, idx) = softmax::fused::online_topk(&logits, k);
        let got_vals = &out_safe[0].as_f32().unwrap()[row * k..(row + 1) * k];
        let got_idx = &out_safe[1].as_i32().unwrap()[row * k..(row + 1) * k];
        let idx32: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
        assert_eq!(got_idx, &idx32[..], "row {row} indices");
        assert_close(got_vals, &vals, 5e-4, "row vals");
    }
    engine.shutdown();
}

#[test]
fn pallas_lowered_kernel_executes_and_matches() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let entry = match engine.manifest().variant("softmax_online_pallas").first() {
        Some(e) => (*e).clone(),
        None => return,
    };
    let (b, v) = (entry.batch, entry.vocab);
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let x = rng.logits(b * v, 5.0);
    let out = engine
        .execute(&entry.name, vec![Tensor::f32(vec![b, v], x.clone()).unwrap()])
        .unwrap();
    let mut expected = vec![0.0; b * v];
    softmax::compute_batch(&x, v, Algorithm::Safe, &mut expected);
    assert_close(out[0].as_f32().unwrap(), &expected, 1e-4, "pallas softmax");
    engine.shutdown();
}

#[test]
fn decode_partial_shards_merge_to_full_vocab_answer() {
    let dir = require_artifacts!();
    let pool = EnginePool::start(&dir, 2).unwrap();
    let part = pool.manifest().bucket_for("decode_partial", 1).unwrap().clone();
    let full = pool.manifest().bucket_for("decode_topk_safe", 1).unwrap().clone();
    let shards = part.shard_count.unwrap();
    let (b, h, vs) = (part.batch, part.hidden.unwrap(), part.vocab);
    let k = part.k.unwrap();
    assert_eq!(part.full_vocab.unwrap(), full.vocab);

    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let hvec = rng.logits(b * h, 1.0);
    let wvec = rng.logits(full.vocab * h, 0.2);

    // full-vocab reference through the runtime
    let out_full = pool
        .engine(0)
        .execute(
            &full.name,
            vec![
                Tensor::f32(vec![b, h], hvec.clone()).unwrap(),
                Tensor::f32(vec![full.vocab, h], wvec.clone()).unwrap(),
            ],
        )
        .unwrap();

    // shard partials + rust-side ⊕ merge (the coordinator's reduction)
    use onlinesoftmax::softmax::MD;
    let mut per_row: Vec<(MD, topk::TopKBuffer)> =
        (0..b).map(|_| (MD::IDENTITY, topk::TopKBuffer::new(k))).collect();
    for s in 0..shards {
        let w_shard = wvec[s * vs * h..(s + 1) * vs * h].to_vec();
        let out = pool
            .engine(s)
            .execute(
                &part.name,
                vec![
                    Tensor::f32(vec![b, h], hvec.clone()).unwrap(),
                    Tensor::f32(vec![vs, h], w_shard).unwrap(),
                ],
            )
            .unwrap();
        let m = out[0].as_f32().unwrap();
        let d = out[1].as_f32().unwrap();
        let u = out[2].as_f32().unwrap();
        let p = out[3].as_i32().unwrap();
        for row in 0..b {
            let (md, buf) = &mut per_row[row];
            *md = md.combine(MD { m: m[row], d: d[row] });
            for i in 0..k {
                let idx = p[row * k + i];
                if idx >= 0 {
                    buf.push(u[row * k + i], idx as i64 + (s * vs) as i64);
                }
            }
        }
    }
    for row in 0..b {
        let (md, buf) = &per_row[row];
        let (vals, idx) = onlinesoftmax::softmax::fused::finalize(buf, *md);
        let want_vals = &out_full[0].as_f32().unwrap()[row * k..(row + 1) * k];
        let want_idx = &out_full[1].as_i32().unwrap()[row * k..(row + 1) * k];
        let idx32: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
        assert_eq!(&idx32[..], want_idx, "row {row}");
        assert_close(&vals, want_vals, 5e-4, "merged vals");
    }
    pool.shutdown();
}

#[test]
fn registered_params_give_identical_results() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    let e = engine.manifest().bucket_for("decode_topk_online", 1).unwrap().clone();
    let (b, h, v) = (e.batch, e.hidden.unwrap(), e.vocab);
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let hvec = rng.logits(b * h, 1.0);
    let wvec = rng.logits(v * h, 0.2);
    let w = Tensor::f32(vec![v, h], wvec).unwrap();

    let inline = engine
        .execute(
            &e.name,
            vec![Tensor::f32(vec![b, h], hvec.clone()).unwrap(), w.clone()],
        )
        .unwrap();

    engine.register_param("W", w).unwrap();
    let via_param = engine
        .execute_mixed(
            &e.name,
            vec![
                Input::Inline(Tensor::f32(vec![b, h], hvec).unwrap()),
                Input::Param("W".into()),
            ],
        )
        .unwrap();
    assert_eq!(inline[1].as_i32().unwrap(), via_param[1].as_i32().unwrap());
    assert_close(
        inline[0].as_f32().unwrap(),
        via_param[0].as_f32().unwrap(),
        1e-6,
        "param vs inline",
    );
    engine.shutdown();
}

#[test]
fn unknown_artifact_and_bad_shapes_error_cleanly() {
    let dir = require_artifacts!();
    let engine = Engine::start(&dir).unwrap();
    assert!(engine.execute("no_such_artifact", vec![]).is_err());
    let entry = engine.manifest().bucket_for("softmax_safe", 1).unwrap();
    let err = engine
        .execute(&entry.name.clone(), vec![Tensor::f32(vec![1, 3], vec![0.0; 3]).unwrap()])
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape"), "{err:#}");
    engine.shutdown();
}

#[test]
fn manifest_loads_all_variants() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for variant in [
        "softmax_safe",
        "softmax_partial",
        "softmax_scale",
        "decode_topk_safe",
        "decode_topk_online",
        "decode_partial",
        "lm_step",
    ] {
        assert!(!m.variant(variant).is_empty(), "variant {variant} missing");
    }
}
