//! Statistical pin on the fused Gumbel-top-k sampler: the empirical
//! first-token distribution over many independent seeded draws must
//! match the exact tempered softmax.  Everything here is **seeded and
//! deterministic** — each "draw" uses a seed derived from a fixed base
//! by `derive_step_seed`, so the chi-squared statistic is a constant of
//! the implementation, not a random variable of the test run.  The
//! thresholds are still quoted against the proper χ² critical values so
//! the margin is interpretable: a correct sampler lands well under the
//! α = 0.001 critical value; a broken draw (wrong hash, wrong u-mapping,
//! biased tie-breaking) lands orders of magnitude above it.

use onlinesoftmax::sample::{self, SampleSpec};

/// Small-vocabulary logit fixture: integer-derived values in [0, 3]
/// (exactly representable in f32), spread enough to be distinguishable
/// but bounded so every bucket's expected count stays ≫ 5.
const V: usize = 32;

fn fixture_logits() -> Vec<f32> {
    (0..V).map(|i| ((i * 7 + 3) % 13) as f32 * 0.25).collect()
}

/// Exact tempered softmax of the fixture, in f64 for reference quality.
fn tempered_softmax(x: &[f32], t: f64) -> Vec<f64> {
    let scaled: Vec<f64> = x.iter().map(|&v| v as f64 / t).collect();
    let m = scaled.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = scaled.iter().map(|&v| (v - m).exp()).collect();
    let d: f64 = exps.iter().sum();
    exps.iter().map(|&e| e / d).collect()
}

/// χ² goodness-of-fit of `draws` seeded first-token samples at
/// temperature `t` against the exact tempered softmax.
fn chi_squared(t: f32, base_seed: u64, draws: usize) -> f64 {
    let x = fixture_logits();
    let mut counts = vec![0usize; V];
    for step in 0..draws as u64 {
        let spec = SampleSpec { seed: sample::derive_step_seed(base_seed, step), temperature: t };
        let (_, idx) = sample::sampled_topk(&x, 1, spec);
        counts[idx[0] as usize] += 1;
    }
    let p = tempered_softmax(&x, t as f64);
    let n = draws as f64;
    counts
        .iter()
        .zip(&p)
        .map(|(&c, &pi)| {
            let expect = n * pi;
            let diff = c as f64 - expect;
            diff * diff / expect
        })
        .sum()
}

/// The α = 0.001 critical value for χ² with V − 1 = 31 degrees of
/// freedom is 61.1: a correctly distributed sampler exceeds it for one
/// run in a thousand *if the seeds were random* — and these seeds are
/// fixed, so the observed statistic is a reproducible constant checked
/// with that value as the explicit non-flaky bound.
const CHI2_CRITICAL_DF31_ALPHA_001: f64 = 61.1;

#[test]
fn first_token_distribution_matches_tempered_softmax() {
    // 20k draws: the smallest bucket's expected count is ≈ 90 at the
    // coldest temperature, comfortably in χ²'s validity regime.
    for (t, base_seed) in [(0.7f32, 0xBA5E_0001u64), (1.0, 0xBA5E_0004), (1.5, 0xBA5E_0003)] {
        let stat = chi_squared(t, base_seed, 20_000);
        assert!(
            stat < CHI2_CRITICAL_DF31_ALPHA_001,
            "T={t}: chi-squared {stat:.1} exceeds the df=31 α=0.001 critical value \
             {CHI2_CRITICAL_DF31_ALPHA_001} — the sampled distribution diverged from \
             the tempered softmax"
        );
    }
}

#[test]
fn temperature_shapes_the_distribution() {
    // Sanity on the *temperature* wiring, not just the draw: colder
    // sampling concentrates mass on the modal token, hotter flattens
    // it.  Deterministic for the same fixed-seed reason as above.
    let x = fixture_logits();
    let modal = x
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i64)
        .unwrap();
    let draws = 4_000u64;
    let mut modal_hits = |t: f32| -> usize {
        (0..draws)
            .filter(|&step| {
                let spec =
                    SampleSpec { seed: sample::derive_step_seed(0xC01D, step), temperature: t };
                let (_, idx) = sample::sampled_topk(&x, 1, spec);
                idx[0] == modal
            })
            .count()
    };
    let cold = modal_hits(0.4);
    let unit = modal_hits(1.0);
    let hot = modal_hits(2.5);
    assert!(
        cold > unit && unit > hot,
        "modal-token frequency must fall with temperature: cold {cold} / unit {unit} / hot {hot}"
    );
    // The fixture has three tied modal tokens; this counts only the
    // first.  Its probability is ≈ 0.17 at T=0.4 vs ≈ 0.09 at T=1 —
    // a gap far outside any sampling noise at 4k draws.
    assert!(cold as f64 > unit as f64 * 1.5, "cold {cold} vs unit {unit}");
}

#[test]
fn chi_squared_detects_an_untempered_sampler() {
    // Negative control: score the *unit*-temperature empirical
    // distribution against the T=0.55 expectation.  If temperature were
    // silently dropped somewhere in the fused path, this is exactly the
    // mismatch the positive tests would face — and the statistic must
    // scream, validating that the α=0.001 bound has real power.
    let x = fixture_logits();
    let mut counts = vec![0usize; V];
    let draws = 20_000u64;
    for step in 0..draws {
        let spec = SampleSpec { seed: sample::derive_step_seed(0xBAD, step), temperature: 1.0 };
        let (_, idx) = sample::sampled_topk(&x, 1, spec);
        counts[idx[0] as usize] += 1;
    }
    let p = tempered_softmax(&x, 0.55);
    let n = draws as f64;
    let stat: f64 = counts
        .iter()
        .zip(&p)
        .map(|(&c, &pi)| {
            let e = n * pi;
            (c as f64 - e) * (c as f64 - e) / e
        })
        .sum();
    assert!(
        stat > 10.0 * CHI2_CRITICAL_DF31_ALPHA_001,
        "mis-tempered distribution only scored {stat:.1}; the goodness-of-fit test \
         would not catch a dropped temperature"
    );
}
