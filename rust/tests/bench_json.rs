//! Schema tests for the machine-readable bench reports (`bench --json`).
//!
//! Runs the real binary end-to-end — `bench --fig backend|sample
//! --smoke --json FILE` — and asserts the emitted documents match the
//! `osmax.bench.backend.v1` / `osmax.bench.sample.v1` schemas that the
//! committed `BENCH_backend.json` trajectory (and any tooling that
//! consumes the reports) depends on.  Unit tests inside `benches::`
//! cover the emitter functions; these cover the CLI plumbing on top, so
//! a regression in either the `--json` flag or a report shape fails
//! loudly.

use std::process::Command;

use onlinesoftmax::json;

#[test]
fn bench_backend_smoke_emits_schema_document() {
    let path = std::env::temp_dir()
        .join(format!("osmax-bench-json-e2e-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let out = Command::new(env!("CARGO_BIN_EXE_onlinesoftmax"))
        .args([
            "bench",
            "--fig",
            "backend",
            "--smoke",
            "--threads",
            "2",
            "--json",
            path.to_str().unwrap(),
        ])
        // Keep the run short regardless of the ambient environment.
        .env("OSMAX_BENCH_FAST", "1")
        .output()
        .expect("spawn bench binary");
    assert!(
        out.status.success(),
        "bench exited with {:?}\nstdout: {}\nstderr: {}",
        out.status.code(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    let text = std::fs::read_to_string(&path).expect("report file written");
    let doc = json::parse(&text).expect("report parses as JSON");

    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.backend.v1");
    assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "backend");
    assert!(
        !doc.get("git").unwrap().as_str().unwrap().is_empty(),
        "git provenance field must be non-empty (`unknown` fallback included)"
    );
    assert_eq!(doc.get("smoke").unwrap().as_bool(), Some(true));
    assert!(doc.get("workers").unwrap().as_f64().unwrap() >= 1.0);
    assert!(doc.get("crossover_elements").unwrap().as_f64().unwrap() >= 1.0);

    let records = doc.get("records").unwrap().as_array().unwrap();
    // Smoke profile: one vocab size × three backend arms.
    assert_eq!(records.len(), 3, "records: {text}");
    let mut backends: Vec<&str> =
        records.iter().map(|r| r.get("backend").unwrap().as_str().unwrap()).collect();
    backends.sort_unstable();
    assert_eq!(backends, ["scalar", "twopass", "vectorized"]);
    for r in records {
        assert!(r.get("vocab").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("batch").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("k").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("p50_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("ns_per_element").unwrap().as_f64().unwrap() > 0.0);
    }

    std::fs::remove_file(&path).ok();
}

#[test]
fn bench_sample_smoke_emits_schema_document() {
    let path = std::env::temp_dir()
        .join(format!("osmax-bench-sample-json-e2e-{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let out = Command::new(env!("CARGO_BIN_EXE_onlinesoftmax"))
        .args([
            "bench",
            "--fig",
            "sample",
            "--smoke",
            "--threads",
            "2",
            "--json",
            path.to_str().unwrap(),
        ])
        .env("OSMAX_BENCH_FAST", "1")
        .output()
        .expect("spawn bench binary");
    assert!(
        out.status.success(),
        "bench exited with {:?}\nstdout: {}\nstderr: {}",
        out.status.code(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    let text = std::fs::read_to_string(&path).expect("report file written");
    let doc = json::parse(&text).expect("report parses as JSON");

    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "osmax.bench.sample.v1");
    assert_eq!(doc.get("fig").unwrap().as_str().unwrap(), "sample");
    assert!(!doc.get("git").unwrap().as_str().unwrap().is_empty());
    assert_eq!(doc.get("smoke").unwrap().as_bool(), Some(true));
    assert!(doc.get("workers").unwrap().as_f64().unwrap() >= 1.0);

    let records = doc.get("records").unwrap().as_array().unwrap();
    // Smoke profile: one vocab size × (greedy, sampled) arms.
    assert_eq!(records.len(), 2, "records: {text}");
    let mut modes: Vec<&str> =
        records.iter().map(|r| r.get("mode").unwrap().as_str().unwrap()).collect();
    modes.sort_unstable();
    assert_eq!(modes, ["greedy", "sampled"]);
    for r in records {
        assert!(r.get("vocab").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("batch").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("k").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("temperature").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("p50_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("ns_per_element").unwrap().as_f64().unwrap() > 0.0);
    }

    std::fs::remove_file(&path).ok();
}
