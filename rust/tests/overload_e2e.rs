//! Overload end-to-end over TCP (host backend — always runs): per-lane
//! admission quotas reject with a *typed* `overloaded` error the client
//! can downcast, deadline-expired queued work is shed before execution
//! (`coordinator.admission.shed`), the connection survives rejections,
//! and the coalescing front's cache serves bitwise-identical replies
//! (`cache.hits` witnessed through the `stats` RPC).
//!
//! Determinism trick (same as `front_coalesce.rs`): a long `max_wait`
//! with one worker parks the first admitted requests in the batcher for
//! the whole flush window, so staggered follow-ups are *guaranteed* to
//! find the lane occupied (quota test) or the leader in flight
//! (coalesce test) — generous margins, no load-dependent racing.

use std::sync::Arc;
use std::time::Duration;

use onlinesoftmax::config::{BackendKind, ServeConfig};
use onlinesoftmax::coordinator::{Coordinator, ErrorCode};
use onlinesoftmax::metrics;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::{client::Client, wire, Server};

struct Running {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn start_server(mut cfg: ServeConfig) -> Running {
    cfg.backend = BackendKind::Host;
    cfg.vocab = 512;
    cfg.hidden = 32;
    cfg.addr = "127.0.0.1:0".into();
    let coordinator = Arc::new(Coordinator::start(&cfg).unwrap());
    let server = Server::bind(&cfg.addr, coordinator, 8).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let thread = std::thread::spawn(move || {
        let _ = server.serve();
    });
    Running { addr, stop, thread: Some(thread) }
}

/// The batcher holds a partial batch for the whole flush window.
const WINDOW: Duration = Duration::from_millis(250);
/// Stagger between submissions — large vs connect/dispatch cost, small
/// vs `WINDOW`.
const STEP: Duration = Duration::from_millis(60);

#[test]
fn lane_quota_rejects_typed_overloaded_and_connection_survives() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.max_wait = WINDOW;
    cfg.admission_batch_cap = 2;
    cfg.cache_capacity = 0;
    cfg.cache_coalesce = false;
    let server = start_server(cfg);
    let rejected = metrics::global().counter("coordinator.admission.rejected.batch");
    let rejected_before = rejected.get();

    // Two batch-priority requests occupy the whole batch lane (cap 2)
    // until the window flushes them.
    let addr = server.addr.clone();
    std::thread::scope(|scope| {
        let occupants: Vec<_> = (0..2)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    client.set_priority(Some("batch"));
                    let mut rng = Xoshiro256pp::seed_from_u64(200 + i);
                    let hidden = rng.logits(32, 1.0);
                    client.decode(&hidden, Some(5)).unwrap()
                })
            })
            .map(|h| {
                std::thread::sleep(STEP);
                h
            })
            .collect();

        // Third batch-priority request: the lane is full, so it is
        // rejected immediately with a structured `overloaded` error —
        // no blocking, no waiting out the window.
        let mut client = Client::connect(&addr).unwrap();
        client.set_priority(Some("batch"));
        let mut rng = Xoshiro256pp::seed_from_u64(300);
        let hidden = rng.logits(32, 1.0);
        let err = client.decode(&hidden, Some(5)).unwrap_err();
        assert_eq!(
            wire::error_code(&err),
            Some(ErrorCode::Overloaded),
            "typed code survives the wire: {err}"
        );
        assert!(format!("{err}").contains("overloaded"), "{err}");
        assert!(rejected.get() > rejected_before, "rejection counter incremented");

        // The same connection keeps working: interactive traffic is
        // not subject to the batch lane's quota, and the transport
        // survived the rejection.
        client.ping().unwrap();
        client.set_priority(Some("interactive"));
        let (vals, idx) = client.decode(&hidden, Some(5)).unwrap();
        assert_eq!(vals.len(), 5);
        assert_eq!(idx.len(), 5);

        for h in occupants {
            let (vals, _) = h.join().unwrap();
            assert_eq!(vals.len(), 5, "lane occupants complete when the window flushes");
        }
    });
}

#[test]
fn queued_work_past_its_deadline_is_shed_with_a_typed_error() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.max_wait = WINDOW;
    cfg.cache_capacity = 0;
    cfg.cache_coalesce = false;
    let server = start_server(cfg);
    let shed = metrics::global().counter("coordinator.admission.shed");
    let shed_before = shed.get();

    // A lone queued request's flush bound IS its deadline, so the
    // worker wakes exactly when the request is already doomed and
    // sheds it instead of executing it.
    let mut client = Client::connect(&server.addr).unwrap();
    client.set_deadline_ms(Some(50));
    let mut rng = Xoshiro256pp::seed_from_u64(400);
    let hidden = rng.logits(32, 1.0);
    let err = client.decode(&hidden, Some(5)).unwrap_err();
    assert_eq!(
        wire::error_code(&err),
        Some(ErrorCode::DeadlineExceeded),
        "typed code survives the wire: {err}"
    );

    // The shed happens on the worker thread at the deadline instant —
    // independent of when the connection thread gave up — so poll
    // briefly rather than racing it.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while shed.get() == shed_before && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(shed.get() > shed_before, "queued expired work was shed, not executed");

    // Connection survives; without a deadline the same request works.
    client.set_deadline_ms(None);
    client.ping().unwrap();
    let (vals, _) = client.decode(&hidden, Some(5)).unwrap();
    assert_eq!(vals.len(), 5);
}

#[test]
fn coalesced_and_cached_wire_replies_are_bitwise_identical() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 2;
    cfg.max_wait = Duration::from_millis(100);
    let server = start_server(cfg);

    let mut rng = Xoshiro256pp::seed_from_u64(500);
    let logits = rng.logits(512, 6.0);

    // Leader + staggered follower: identical payloads, the follower is
    // guaranteed to arrive while the leader waits out the window.
    let (first, second) = std::thread::scope(|scope| {
        let leader = {
            let addr = server.addr.clone();
            let logits = logits.clone();
            scope.spawn(move || Client::connect(&addr).unwrap().softmax(&logits).unwrap())
        };
        std::thread::sleep(Duration::from_millis(30));
        let follower = {
            let addr = server.addr.clone();
            let logits = logits.clone();
            scope.spawn(move || Client::connect(&addr).unwrap().softmax(&logits).unwrap())
        };
        (leader.join().unwrap(), follower.join().unwrap())
    });

    // A later identical request is served from the result cache.
    let mut client = Client::connect(&server.addr).unwrap();
    let cached = client.softmax(&logits).unwrap();

    let bits = |probs: &[f32]| probs.iter().map(|p| p.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&second), bits(&first), "coalesced reply bitwise identical");
    assert_eq!(bits(&cached), bits(&first), "cached reply bitwise identical");

    // The `stats` RPC exposes this instance's front counters.
    let stats = client.stats().unwrap();
    let cache = stats.get("cache").expect("stats carries a cache object");
    let count = |k: &str| cache.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(count("misses"), 1.0, "one execution for three identical requests");
    assert_eq!(count("coalesced"), 1.0);
    assert_eq!(count("hits"), 1.0);
    assert_eq!(count("entries"), 1.0);
    assert!(
        metrics::global().counter("coordinator.cache.hits").get() > 0,
        "process-global cache-hit counter witnessed"
    );
}
