//! v2 protocol end-to-end over TCP: server-side streaming generation,
//! per-request options, structured errors, and v1 wire compatibility.
//!
//! This suite is the acceptance gate for the typed v2 serving API:
//!
//! * a `generate` request for N tokens completes over a single
//!   connection with N streamed token frames, **bitwise-identical**
//!   to N sequential v1 `lm_step` calls;
//! * v1 wire requests (no `"v"` field) still decode and serve
//!   unchanged;
//! * concurrent streams demonstrably share decode batches
//!   (`coordinator.batch.lm_step.peak` > 1);
//! * malformed / oversized / wrong-version frames produce structured
//!   errors without killing the connection.
//!
//! Host backend only — no artifacts, so CI always runs it.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use onlinesoftmax::config::{BackendKind, ServeConfig, ServingMode};
use onlinesoftmax::coordinator::Coordinator;
use onlinesoftmax::json::{self, Value};
use onlinesoftmax::metrics;
use onlinesoftmax::server::{client::Client, Server, MAX_FRAME_BYTES};

struct Running {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for Running {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn host_config() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.mode = ServingMode::Online;
    cfg.vocab = 2048;
    cfg.hidden = 32;
    cfg.host_shards = 4;
    cfg.shard_threshold = 512;
    cfg.grid_rows = 4;
    cfg.workers = 2;
    cfg.max_wait = Duration::from_micros(500);
    cfg.addr = "127.0.0.1:0".into();
    cfg
}

fn start_server(cfg: &ServeConfig) -> Running {
    let coordinator = Arc::new(Coordinator::start(cfg).unwrap());
    let server = Server::bind(&cfg.addr, coordinator, 16).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let thread = std::thread::spawn(move || {
        let _ = server.serve();
    });
    Running { addr, stop, thread: Some(thread) }
}

/// Raw line-JSON connection for speaking exact wire bytes (v1 frames,
/// malformed frames) without the typed client in the way.
struct RawConn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        let writer = stream.try_clone().unwrap();
        RawConn { writer, reader: BufReader::new(stream) }
    }

    fn roundtrip(&mut self, line: &str) -> Value {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        self.read_frame()
    }

    fn read_frame(&mut self) -> Value {
        let mut response = String::new();
        let n = self.reader.read_line(&mut response).unwrap();
        assert!(n > 0, "server closed connection");
        json::parse(response.trim()).unwrap()
    }
}

fn topk_of(v: &Value) -> (Vec<f32>, Vec<i64>) {
    let vals = v.get("vals").unwrap().to_f32_vec().unwrap();
    let idx: Vec<i64> = v
        .get("idx")
        .unwrap()
        .to_i32_vec()
        .unwrap()
        .into_iter()
        .map(|i| i as i64)
        .collect();
    (vals, idx)
}

/// The acceptance pin: one v2 `generate` stream reproduces N
/// sequential **v1-wire** `lm_step` calls bitwise, over one connection.
#[test]
fn generate_stream_matches_sequential_v1_lm_steps() {
    let server = start_server(&host_config());
    const N: usize = 6;
    const K: usize = 5;
    let prompt = [7i32, 42];

    // v2 streaming path.
    let mut client = Client::connect(&server.addr).unwrap();
    let s_gen = client.open_session().unwrap();
    let frames = client.generate_all(s_gen, &prompt, N, Some(K)).unwrap();
    assert_eq!(frames.len(), N, "one streamed frame per requested token");

    // Reference path: raw v1 frames (no "v" field), one round-trip per
    // token, fresh session on the same server.
    let mut raw = RawConn::connect(&server.addr);
    let opened = raw.roundtrip(r#"{"op":"open_session"}"#);
    assert_eq!(opened.get("ok").and_then(Value::as_bool), Some(true));
    assert!(opened.get("v").is_none(), "v1 responses carry no version field");
    let s_ref = opened.get("session").unwrap().as_i64().unwrap();

    // Feed the prompt prefix exactly like the server-side loop does.
    for &t in &prompt[..prompt.len() - 1] {
        let r = raw.roundtrip(&format!(
            r#"{{"op":"lm_step","session":{s_ref},"token":{t},"k":{K}}}"#
        ));
        assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
    }
    let mut cur = *prompt.last().unwrap();
    for (i, frame) in frames.iter().enumerate() {
        let r = raw.roundtrip(&format!(
            r#"{{"op":"lm_step","session":{s_ref},"token":{cur},"k":{K}}}"#
        ));
        assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true), "v1 step {i}");
        let (vals, idx) = topk_of(&r);
        assert_eq!(frame.vals, vals, "step {i}: bitwise-identical probabilities");
        assert_eq!(frame.idx, idx, "step {i}: identical selections");
        assert_eq!(frame.index, i);
        cur = idx[0] as i32;
        assert_eq!(frame.token, cur, "step {i}: same greedy choice");
    }
}

/// Seeded sampling end to end over TCP: the same `(seed, temperature)`
/// replays a bitwise-identical stream — tokens, candidate sets, and
/// probabilities — on fresh sessions and fresh connections; a different
/// seed takes a different trajectory; and the sampling-free request
/// shape is pinned to stay exactly the greedy decode it always was.
#[test]
fn sampled_generate_is_seed_reproducible_over_the_wire() {
    let server = start_server(&host_config());
    const N: usize = 6;
    let prompt = [3i32, 9];

    let run = |seed: Option<u64>, temperature: Option<f32>| {
        let mut client = Client::connect(&server.addr).unwrap();
        client.set_seed(seed);
        client.set_temperature(temperature);
        let sid = client.open_session().unwrap();
        let frames = client.generate_all(sid, &prompt, N, Some(4)).unwrap();
        assert_eq!(frames.len(), N);
        frames
            .iter()
            .map(|f| (f.token, f.idx.clone(), f.vals.clone()))
            .collect::<Vec<_>>()
    };

    // Same seed ⇒ bitwise-identical stream, across connections/sessions.
    let a = run(Some(42), Some(0.8));
    let b = run(Some(42), Some(0.8));
    assert_eq!(a, b, "same seed must replay the stream bitwise");

    // A different seed diverges (different perturbation stream).
    let c = run(Some(43), Some(0.8));
    assert_ne!(a, c, "different seeds must take different trajectories");

    // Greedy regression pin: no sampling options ≡ explicit neutral
    // temperature — the pre-sampling wire shape still serves the exact
    // greedy decode.
    let greedy = run(None, None);
    let neutral = run(None, Some(1.0));
    assert_eq!(greedy, neutral, "temperature 1.0 without a seed is greedy");
    assert_ne!(a, greedy, "a tempered seeded stream is not the greedy stream");
}

/// The stateless sampled ops over the wire: seeded `decode` is
/// reproducible and seed-sensitive, and tempered decode *without* a
/// seed is refused with the typed error (the executor-side pairing
/// rule, observed end to end).
#[test]
fn sampled_decode_over_the_wire_is_seeded_and_validated() {
    let server = start_server(&host_config());
    let mut client = Client::connect(&server.addr).unwrap();
    let hidden: Vec<f32> = (0..32).map(|i| (i as f32) * 0.1 - 1.5).collect();

    client.set_seed(Some(7));
    client.set_temperature(Some(0.7));
    let a = client.decode(&hidden, Some(5)).unwrap();
    let b = client.decode(&hidden, Some(5)).unwrap();
    assert_eq!(a, b, "same seed, same payload ⇒ same sampled answer");

    client.set_seed(Some(8));
    let c = client.decode(&hidden, Some(5)).unwrap();
    assert_ne!(a, c, "a different seed must sample differently");

    // Tempered greedy is a typed invalid_argument, not a silent fallback.
    client.set_seed(None);
    let err = client.decode(&hidden, Some(5)).unwrap_err();
    assert!(format!("{err}").contains("invalid_argument"), "{err}");

    // The connection survives and plain greedy still serves.
    client.set_temperature(None);
    let (vals, _) = client.decode(&hidden, Some(5)).unwrap();
    assert_eq!(vals.len(), 5);
}

/// Concurrent generation streams must share decode batches: the
/// whole point of moving the loop server-side.  Witnessed by the
/// `coordinator.batch.lm_step.peak` gauge (a monotone high-water mark
/// that only multi-request batches can push past 1), and each stream
/// must still get its own exact trajectory.
#[test]
fn concurrent_streams_share_decode_batches() {
    let mut cfg = host_config();
    // A generous batching window so the aligned first steps of every
    // stream provably coalesce; afterwards the streams stay in
    // lockstep because their steps complete together.
    cfg.max_wait = Duration::from_millis(20);
    cfg.max_batch = 16;
    let server = start_server(&cfg);

    const STREAMS: usize = 4;
    const TOKENS: usize = 8;
    let barrier = Arc::new(Barrier::new(STREAMS));
    let outcomes: Vec<(i32, Vec<i32>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..STREAMS)
            .map(|w| {
                let addr = server.addr.clone();
                let barrier = barrier.clone();
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    let sid = client.open_session().unwrap();
                    let start = 11 * (w as i32 + 1);
                    barrier.wait();
                    let frames =
                        client.generate_all(sid, &[start], TOKENS, Some(5)).unwrap();
                    assert_eq!(frames.len(), TOKENS);
                    (start, frames.iter().map(|f| f.token).collect::<Vec<i32>>())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let peak = metrics::global().gauge("coordinator.batch.lm_step.peak").get();
    assert!(
        peak > 1,
        "concurrent streams must share decode batches (lm_step peak occupancy {peak})"
    );

    // Row integrity under cross-stream batching: replay each stream
    // alone and require the identical trajectory.
    let mut client = Client::connect(&server.addr).unwrap();
    for (start, tokens) in &outcomes {
        let sid = client.open_session().unwrap();
        let frames = client.generate_all(sid, &[*start], TOKENS, Some(5)).unwrap();
        let replay: Vec<i32> = frames.iter().map(|f| f.token).collect();
        assert_eq!(
            &replay, tokens,
            "stream from token {start}: batched and solo trajectories match"
        );
    }
}

/// v1 frames keep working verbatim, and v1 errors keep their
/// message-string shape (now with a machine-readable `code` alongside).
#[test]
fn v1_wire_requests_still_serve_unchanged() {
    let server = start_server(&host_config());
    let mut raw = RawConn::connect(&server.addr);

    let r = raw.roundtrip(r#"{"op":"ping"}"#);
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
    assert!(r.get("v").is_none());

    // softmax
    let logits: Vec<String> = (0..2048).map(|i| format!("{}", (i % 7) as f32 * 0.5)).collect();
    let r = raw.roundtrip(&format!(r#"{{"op":"softmax","logits":[{}]}}"#, logits.join(",")));
    let probs = r.get("probs").unwrap().to_f32_vec().unwrap();
    assert_eq!(probs.len(), 2048);
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");

    // decode with k
    let hidden: Vec<String> = (0..32).map(|i| format!("0.{}", i % 10)).collect();
    let r = raw.roundtrip(&format!(
        r#"{{"op":"decode","hidden":[{}],"k":3}}"#,
        hidden.join(",")
    ));
    let (vals, idx) = topk_of(&r);
    assert_eq!(vals.len(), 3);
    assert!(idx.iter().all(|&i| i >= 0 && (i as usize) < 2048));

    // sessions over v1
    let r = raw.roundtrip(r#"{"op":"open_session"}"#);
    let sid = r.get("session").unwrap().as_i64().unwrap();
    let r = raw.roundtrip(&format!(r#"{{"op":"lm_step","session":{sid},"token":4,"k":3}}"#));
    assert_eq!(topk_of(&r).0.len(), 3);
    let r = raw.roundtrip(&format!(r#"{{"op":"close_session","session":{sid}}}"#));
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));

    // v1 error shape: `error` is a string, `code` rides along.
    let r = raw.roundtrip(&format!(r#"{{"op":"lm_step","session":{sid},"token":4}}"#));
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(false));
    let msg = r.get("error").and_then(Value::as_str).unwrap();
    assert!(msg.contains("unknown session"), "{msg}");
    assert_eq!(r.get("code").and_then(Value::as_str), Some("not_found"));

    // `generate` is refused on v1 with a pointer to v2.
    let r = raw.roundtrip(r#"{"op":"generate","session":1,"prompt":[1],"max_tokens":2}"#);
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(false));
    assert!(r.get("error").and_then(Value::as_str).unwrap().contains("v2"));
}

/// Malformed, wrong-version, and oversized frames all produce
/// structured errors and leave the connection serving.
#[test]
fn bad_frames_get_structured_errors_and_connection_survives() {
    let server = start_server(&host_config());
    let mut raw = RawConn::connect(&server.addr);

    // malformed json → v1-shaped error with a code
    let r = raw.roundtrip("this is not json");
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(false));
    assert_eq!(r.get("code").and_then(Value::as_str), Some("bad_request"));

    // unsupported version → v2 structured error
    let r = raw.roundtrip(r#"{"v":3,"op":"ping"}"#);
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(false));
    let err = r.get("error").unwrap();
    assert_eq!(err.get("code").and_then(Value::as_str), Some("bad_request"));
    assert!(err.get("message").and_then(Value::as_str).unwrap().contains("version"));

    // v2 structured validation error
    let r = raw.roundtrip(r#"{"v":2,"op":"decode","hidden":[0.5],"temperature":0.7}"#);
    let err = r.get("error").unwrap();
    assert_eq!(err.get("code").and_then(Value::as_str), Some("invalid_argument"));

    // oversized frame → answered and discarded without buffering it;
    // the frame never parsed, so the error uses the v1 compatibility
    // shape (string `error` + `code` rider) like other pre-parse
    // failures
    let chunk = vec![b'a'; 64 * 1024];
    let mut sent = 0usize;
    while sent <= MAX_FRAME_BYTES + chunk.len() {
        raw.writer.write_all(&chunk).unwrap();
        sent += chunk.len();
    }
    raw.writer.write_all(b"\n").unwrap();
    raw.writer.flush().unwrap();
    let r = raw.read_frame();
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(false));
    assert_eq!(r.get("code").and_then(Value::as_str), Some("bad_request"));
    assert!(r.get("error").and_then(Value::as_str).unwrap().contains("exceeds"));

    // the connection still serves
    let r = raw.roundtrip(r#"{"op":"ping"}"#);
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
}

/// Per-request deadlines are honored end to end, and stream errors are
/// typed.
#[test]
fn deadlines_and_stream_errors_are_typed() {
    let server = start_server(&host_config());
    let mut client = Client::connect(&server.addr).unwrap();

    // An already-expired deadline is rejected with deadline_exceeded
    // instead of being executed.
    let zeros = [0.0f32; 32];
    client.set_deadline_ms(Some(0));
    let err = client.decode(&zeros, Some(3)).unwrap_err();
    assert!(format!("{err}").contains("deadline_exceeded"), "{err}");
    client.set_deadline_ms(None);
    client.decode(&zeros, Some(3)).unwrap();

    // Unknown-session generation fails the stream with not_found.
    let mut stream = client.generate(999_999, &[1], 3, None).unwrap();
    let first = stream.next().unwrap();
    let err = first.unwrap_err();
    assert!(format!("{err}").contains("not_found"), "{err}");
    assert!(stream.next().is_none(), "stream is finished after the error");
    drop(stream);

    // Zero-budget stream deadline is typed too.
    let sid = client.open_session().unwrap();
    client.set_deadline_ms(Some(0));
    let mut stream = client.generate(sid, &[1], 3, None).unwrap();
    let err = stream.next().unwrap().unwrap_err();
    assert!(format!("{err}").contains("deadline_exceeded"), "{err}");
    client.set_deadline_ms(None);

    // The connection survives failed streams.
    client.ping().unwrap();
}

/// A final frame without a trailing newline is still served at EOF
/// (legacy `read_line` behavior, kept by the framed read loop).
#[test]
fn final_frame_without_newline_is_served_at_eof() {
    let server = start_server(&host_config());
    let mut raw = RawConn::connect(&server.addr);
    raw.writer.write_all(br#"{"op":"ping"}"#).unwrap();
    raw.writer.flush().unwrap();
    raw.writer.shutdown(std::net::Shutdown::Write).unwrap();
    let r = raw.read_frame();
    assert_eq!(r.get("ok").and_then(Value::as_bool), Some(true));
}

/// Abandoning a stream early drains it to the terminal frame
/// (`Generation`'s Drop), so the connection keeps serving in order.
#[test]
fn abandoned_stream_does_not_desync_the_connection() {
    let server = start_server(&host_config());
    let mut client = Client::connect(&server.addr).unwrap();
    let sid = client.open_session().unwrap();
    {
        let mut stream = client.generate(sid, &[3], 8, Some(5)).unwrap();
        let first = stream.next().unwrap().unwrap();
        assert_eq!(first.index, 0);
        // dropped here after one of eight frames: Drop must consume
        // the rest plus the terminal frame
    }
    client.ping().unwrap();
    let (vals, _) = client.decode(&[0.0; 32], Some(3)).unwrap();
    assert_eq!(vals.len(), 3);
}

/// The v2 `stats` reply exposes coordinator queue depth, per-class
/// depths, and the active stream count.
#[test]
fn stats_reports_queues_and_streams() {
    let server = start_server(&host_config());
    let mut client = Client::connect(&server.addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.get("v").and_then(Value::as_i64), Some(2));
    assert!(stats.get("metrics").is_some());
    assert!(stats.get("queue_depth").and_then(Value::as_i64).is_some());
    let depths = stats.get("queue_depths").unwrap();
    for class in ["softmax", "decode", "lm_step"] {
        assert!(
            depths.get(class).and_then(Value::as_i64).is_some(),
            "queue_depths.{class} present"
        );
    }
    assert!(stats.get("active_streams").and_then(Value::as_i64).is_some());
    assert!(stats.get("sessions").and_then(Value::as_i64).is_some());
}
