//! Stress suite for the [`exec::ThreadPool`] scheduling policies: the
//! contracts the shard layer leans on (scoped joins, drop-drains,
//! panic isolation) exercised under concurrency, for both the FIFO
//! injector and the work-stealing deques, plus a torture case that
//! deterministically forces steals.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use onlinesoftmax::exec::{bounded, SchedPolicy, ThreadPool};

const POLICIES: [SchedPolicy; 2] = [SchedPolicy::Fifo, SchedPolicy::Steal];

/// Spin until `cond` holds, panicking after `secs` seconds — keeps a
/// scheduler bug a loud failure instead of a hung test binary.
fn spin_until(secs: u64, what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

#[test]
fn concurrent_run_scoped_from_many_threads() {
    // Several caller threads fan out scoped batches on ONE shared pool
    // concurrently: every batch must see exactly its own tasks join.
    for policy in POLICIES {
        let pool = ThreadPool::with_policy(4, "stress", policy);
        let pool = &pool;
        std::thread::scope(|scope| {
            for caller in 0..6usize {
                scope.spawn(move || {
                    for round in 0..20usize {
                        let n = 1 + (caller + round) % 7;
                        let hits = AtomicUsize::new(0);
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
                            .map(|_| {
                                let hits = &hits;
                                Box::new(move || {
                                    hits.fetch_add(1, Ordering::SeqCst);
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_scoped(tasks);
                        // The scoped join covers exactly this batch —
                        // no lost tasks, no early return.
                        assert_eq!(
                            hits.load(Ordering::SeqCst),
                            n,
                            "{policy:?} caller {caller} round {round}"
                        );
                    }
                });
            }
        });
        pool.join_idle();
        assert_eq!(pool.queued(), 0);
    }
}

#[test]
fn drop_while_queued_runs_everything() {
    // Drop the pool while most of the batch is still queued: the
    // drop-drains contract says every accepted task runs before the
    // drop returns, under either policy.
    for policy in POLICIES {
        let ran = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::with_policy(2, "stress", policy);
            let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..600)
                .map(|_| {
                    let ran = ran.clone();
                    Box::new(move || {
                        std::thread::sleep(Duration::from_micros(20));
                        ran.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + 'static>
                })
                .collect();
            pool.execute_all(tasks);
            // A few singles through the injector submission channel too.
            for _ in 0..10 {
                let ran = ran.clone();
                pool.execute(move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop here, with the queues still deep
        assert_eq!(ran.load(Ordering::SeqCst), 610, "{policy:?}");
    }
}

#[test]
fn steal_torture_one_long_tile_many_short() {
    // Deterministically force steals: 4 workers, a batch whose LAST two
    // tasks are stragglers that spin until every short task has
    // completed.  The stragglers land at the owner end (LIFO) of two
    // deques, so those deques' owners claim them next and pin
    // themselves; the shorts buried beneath the stragglers can then
    // ONLY run if the free workers steal them (FIFO, from the far
    // end).  If stealing is broken this deadlocks — caught by the spin
    // timeout inside the straggler.
    //
    // Rendezvous is by blocking channels, not timing: shorts block on a
    // gate the main thread fills only *after* `execute_all` returns, so
    // an eagerly-woken worker can claim at most one short and then
    // blocks until the stragglers are in place — no deque can be
    // drained early, and the ≥ 1 steal below is deterministic under any
    // OS schedule.  Every wait carries a timeout so a scheduler bug is
    // a loud failure rather than a hung binary.
    const SHORTS: usize = 120;
    const PATIENCE: Duration = Duration::from_secs(30);
    let pool = ThreadPool::with_policy(4, "torture", SchedPolicy::Steal);
    let (steals_before, _, _) = pool.steal_stats();
    let (gate_tx, gate_rx) = bounded::<()>(SHORTS);
    let (release_tx, release_rx) = bounded::<()>(2);
    let done_shorts = Arc::new(AtomicUsize::new(0));

    let mut tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = Vec::new();
    for _ in 0..SHORTS {
        let gate_rx = gate_rx.clone();
        let release_tx = release_tx.clone();
        let done_shorts = done_shorts.clone();
        tasks.push(Box::new(move || {
            gate_rx.recv_timeout(PATIENCE).expect("gate opens once the batch is submitted");
            if done_shorts.fetch_add(1, Ordering::SeqCst) + 1 == SHORTS {
                // Last short through: release both stragglers.
                release_tx.send(()).unwrap();
                release_tx.send(()).unwrap();
            }
        }));
    }
    for _ in 0..2 {
        let release_rx = release_rx.clone();
        tasks.push(Box::new(move || {
            release_rx
                .recv_timeout(PATIENCE)
                .expect("straggler starved: shorts not stolen from its deque");
        }));
    }
    pool.execute_all(tasks);
    // Open the gate only now: every task — stragglers at the LIFO end
    // of their deques included — is placed before any short completes.
    for _ in 0..SHORTS {
        gate_tx.send(()).unwrap();
    }
    pool.join_idle();

    assert_eq!(done_shorts.load(Ordering::SeqCst), SHORTS);
    assert_eq!(pool.queued(), 0);
    // Metric sanity: the scenario cannot complete without stealing, and
    // steals can never exceed the tasks that existed.  (The counter is
    // process-global, hence the before/after delta and the loose upper
    // bound across concurrently-running tests.)
    let (steals_after, _, overflows) = pool.steal_stats();
    assert!(
        steals_after > steals_before,
        "completing the torture batch requires at least one steal"
    );
    let _ = overflows; // bounded deques may or may not overflow here
}

#[test]
fn steal_pool_handles_burst_of_scoped_grids() {
    // Many back-to-back scoped dispatches (the shard engine's dispatch
    // pattern) with mixed task durations: exercises scatter, LIFO pop,
    // steal, park, and re-wake transitions repeatedly.
    let pool = ThreadPool::with_policy(3, "burst", SchedPolicy::Steal);
    let total = AtomicUsize::new(0);
    for round in 0..40usize {
        let n = 1 + round % 11;
        let total = &total;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
            .map(|i| {
                Box::new(move || {
                    if i % 3 == 0 {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                    total.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
    }
    let want: usize = (0..40usize).map(|r| 1 + r % 11).sum();
    assert_eq!(total.load(Ordering::SeqCst), want);
    pool.join_idle();
    assert_eq!(pool.queued(), 0);
}

#[test]
fn panicking_tasks_do_not_wedge_either_policy() {
    for policy in POLICIES {
        let pool = ThreadPool::with_policy(2, "stress", policy);
        let ok = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..20)
            .map(|i| {
                let ok = &ok;
                Box::new(move || {
                    if i % 4 == 0 {
                        panic!("tile {i} failed");
                    }
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks); // must join despite the 5 panics
        assert_eq!(ok.load(Ordering::SeqCst), 15, "{policy:?}");
        // and the pool still accepts work afterwards
        let after = Arc::new(AtomicUsize::new(0));
        let a = after.clone();
        pool.execute(move || {
            a.store(1, Ordering::SeqCst);
        });
        spin_until(10, "post-panic task", || after.load(Ordering::SeqCst) == 1);
    }
}

/// With `--features osmax_model` the deterministic-schedule explorer is
/// compiled into the library, so integration tests can verify under
/// *every* bounded schedule what the torture test above exercises under
/// one OS schedule: an owner and a thief racing a deque down to its
/// last elements neither lose nor duplicate a task.
#[cfg(feature = "osmax_model")]
#[test]
fn model_checked_steal_race_conserves_tasks() {
    use onlinesoftmax::exec::{model, StealDeque};

    model::check("pool_stress_steal_race", model::Config::small(), || {
        let dq = Arc::new(StealDeque::new(4));
        dq.push(1).unwrap();
        dq.push(2).unwrap();
        let thief = {
            let dq = Arc::clone(&dq);
            model::spawn(move || dq.steal())
        };
        let a = dq.pop();
        let b = dq.pop();
        let stolen = thief.join().flatten();
        let mut got: Vec<i32> = [a, b, stolen].into_iter().flatten().collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "every task surfaces exactly once");
    });
}
