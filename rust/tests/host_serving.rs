//! Host-backend serving integration: the full coordinator stack
//! (batcher → executor → shard engine → ⊕ reduction) with NO artifacts,
//! NO PJRT, and NO python — this suite always runs, making the serving
//! path part of the green `cargo test` gate rather than an
//! artifact-gated extra.

use std::sync::Arc;
use std::time::Duration;

use onlinesoftmax::config::{BackendKind, ServeConfig, ServingMode};
use onlinesoftmax::coordinator::{beam, Coordinator, Payload, Reply, RequestOptions};
use onlinesoftmax::metrics;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::server::{client::Client, Server};
use onlinesoftmax::shard::ShardBackendKind;
use onlinesoftmax::softmax::{fused, scalar};

const TIMEOUT: Duration = Duration::from_secs(60);

/// Small, fast host config: vocabulary above the shard threshold so the
/// sharded path actually engages, and `grid_rows > 1` so every batched
/// request in this suite exercises the batch×shard grid scheduler (CI
/// runs this suite as the grid e2e gate).
///
/// The shard-pool scheduling policy is inherited from
/// `ServeConfig::default()`, which honours `OSMAX_POOL_SCHED` — CI's
/// scheduler matrix runs this whole suite under both `fifo` and
/// `steal` without the tests naming a policy.
fn host_config(mode: ServingMode, shard_threshold: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.backend = BackendKind::Host;
    cfg.mode = mode;
    cfg.vocab = 2048;
    cfg.hidden = 32;
    cfg.host_shards = 4;
    cfg.shard_threshold = shard_threshold;
    cfg.grid_rows = 4;
    cfg.workers = 2;
    cfg.max_wait = Duration::from_micros(500);
    cfg
}

fn close(a: f32, b: f32, rtol: f32) -> bool {
    (a - b).abs() <= 1e-7 + rtol * a.abs().max(b.abs())
}

#[test]
fn host_softmax_matches_scalar_reference() {
    // Threshold 512 < vocab 2048: requests take the sharded path.
    let coord = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    assert!(coord.executor().is_host_backend());
    let vocab = coord.executor().vocab();
    assert_eq!(vocab, 2048);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let logits = rng.logits(vocab, 8.0);
    match coord.call(Payload::Softmax { logits: logits.clone() }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => {
            let mut want = vec![0.0; vocab];
            scalar::safe(&logits, &mut want);
            assert_eq!(probs.len(), vocab);
            for (i, (a, b)) in probs.iter().zip(&want).enumerate() {
                assert!(close(*a, *b, 1e-4), "idx {i}: {a} vs {b}");
            }
        }
        other => panic!("unexpected {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn host_sharded_equals_serial_fallback() {
    // Same request through the sharded path (low threshold) and the
    // single-thread fallback (threshold above vocab): identical indices
    // and near-identical probabilities.
    let sharded = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    let serial = Coordinator::start(&host_config(ServingMode::Online, 1_000_000)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let logits = rng.logits(2048, 10.0);
    let ps = match sharded.call(Payload::Softmax { logits: logits.clone() }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => probs,
        other => panic!("{other:?}"),
    };
    let pu = match serial.call(Payload::Softmax { logits }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => probs,
        other => panic!("{other:?}"),
    };
    for (i, (a, b)) in ps.iter().zip(&pu).enumerate() {
        assert!(close(*a, *b, 1e-4), "idx {i}: {a} vs {b}");
    }

    let hidden = rng.logits(32, 1.0);
    let d_sharded = sharded
        .call_opts(
            Payload::DecodeTopK { hidden: hidden.clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        )
        .unwrap();
    let d_serial = serial
        .call_opts(Payload::DecodeTopK { hidden }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    match (d_sharded, d_serial) {
        (Reply::TopK { vals: v1, idx: i1 }, Reply::TopK { vals: v2, idx: i2 }) => {
            assert_eq!(i1, i2, "sharded and serial decode select the same tokens");
            for (a, b) in v1.iter().zip(&v2) {
                assert!(close(*a, *b, 1e-4), "{a} vs {b}");
            }
        }
        other => panic!("{other:?}"),
    }
    sharded.shutdown();
    serial.shutdown();
}

#[test]
fn host_decode_matches_reference_and_modes_agree() {
    let online = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    let safe = Coordinator::start(&host_config(ServingMode::Safe, 1_000_000)).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let hidden = rng.logits(32, 1.0);

    let (vals_o, idx_o) = match online
        .call_opts(
            Payload::DecodeTopK { hidden: hidden.clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        )
        .unwrap()
    {
        Reply::TopK { vals, idx } => (vals, idx),
        other => panic!("{other:?}"),
    };
    let (vals_s, idx_s) = match safe
        .call_opts(
            Payload::DecodeTopK { hidden: hidden.clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        )
        .unwrap()
    {
        Reply::TopK { vals, idx } => (vals, idx),
        other => panic!("{other:?}"),
    };
    assert_eq!(idx_o, idx_s, "online and safe modes select the same tokens");
    for (a, b) in vals_o.iter().zip(&vals_s) {
        assert!(close(*a, *b, 1e-3), "{a} vs {b}");
    }

    // cross-check against the host-side reference projection + Alg 4
    let logits = online.executor().model().project_row(&hidden);
    let (want_vals, want_idx) = fused::online_topk(&logits, 5);
    assert_eq!(idx_o, want_idx);
    for (a, b) in vals_o.iter().zip(&want_vals) {
        assert!(close(*a, *b, 1e-3), "{a} vs {b}");
    }
    online.shutdown();
    safe.shutdown();
}

#[test]
fn host_batched_requests_get_individual_answers() {
    let mut cfg = host_config(ServingMode::Online, 512);
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(20); // force a batching window
    let coord = Coordinator::start(&cfg).unwrap();
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let inputs: Vec<Vec<f32>> = (0..6).map(|_| rng.logits(vocab, 5.0)).collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|l| coord.submit(Payload::Softmax { logits: l.clone() }).unwrap())
        .collect();
    for (input, rx) in inputs.iter().zip(rxs) {
        match rx.recv_timeout(TIMEOUT).unwrap().unwrap() {
            Reply::Softmax { probs } => {
                let max_i =
                    probs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
                let want_i =
                    input.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
                assert_eq!(max_i, want_i, "each request got its own answer");
            }
            other => panic!("{other:?}"),
        }
    }
    coord.shutdown();
}

#[test]
fn host_grid_batches_are_bitwise_identical_to_per_row_dispatch() {
    // The same burst of requests served through (a) the batch×shard
    // grid (grid_rows > 1, whole batches tiled in one dispatch) and
    // (b) forced per-row dispatch (grid_rows = 1, the degenerate 1×S
    // grid) must produce byte-for-byte identical replies — the grid is
    // a scheduling change, never a numerics change.
    let mut grid_cfg = host_config(ServingMode::Online, 512);
    grid_cfg.max_batch = 8;
    grid_cfg.max_wait = Duration::from_millis(20); // force a batching window
    let mut row_cfg = grid_cfg.clone();
    grid_cfg.grid_rows = 0; // whole batch per grid
    row_cfg.grid_rows = 1; // per-row dispatch
    let grid = Coordinator::start(&grid_cfg).unwrap();
    let per_row = Coordinator::start(&row_cfg).unwrap();

    let vocab = grid.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let logits: Vec<Vec<f32>> = (0..6).map(|_| rng.logits(vocab, 6.0)).collect();
    let hiddens: Vec<Vec<f32>> = (0..6).map(|_| rng.logits(32, 1.0)).collect();

    let rx_a: Vec<_> = logits
        .iter()
        .map(|l| grid.submit(Payload::Softmax { logits: l.clone() }).unwrap())
        .collect();
    let rx_b: Vec<_> = logits
        .iter()
        .map(|l| per_row.submit(Payload::Softmax { logits: l.clone() }).unwrap())
        .collect();
    for (ra, rb) in rx_a.into_iter().zip(rx_b) {
        let pa = ra.recv_timeout(TIMEOUT).unwrap().unwrap();
        let pb = rb.recv_timeout(TIMEOUT).unwrap().unwrap();
        assert_eq!(pa, pb, "grid and per-row softmax replies must match bitwise");
    }

    let rx_a: Vec<_> = hiddens
        .iter()
        .map(|h| {
            grid.submit_opts(Payload::DecodeTopK { hidden: h.clone() }, RequestOptions::with_k(7))
                .unwrap()
        })
        .collect();
    let rx_b: Vec<_> = hiddens
        .iter()
        .map(|h| {
            per_row
                .submit_opts(Payload::DecodeTopK { hidden: h.clone() }, RequestOptions::with_k(7))
                .unwrap()
        })
        .collect();
    for (ra, rb) in rx_a.into_iter().zip(rx_b) {
        let da = ra.recv_timeout(TIMEOUT).unwrap().unwrap();
        let db = rb.recv_timeout(TIMEOUT).unwrap().unwrap();
        assert_eq!(da, db, "grid and per-row decode replies must match bitwise");
    }
    grid.shutdown();
    per_row.shutdown();
}

#[test]
fn host_artifacts_stub_backend_serves_via_per_tile_fallback() {
    // The e2e proof of the fallback protocol: a coordinator whose shard
    // backend is the PJRT contract stub must (a) answer every request
    // correctly — each declined tile is rerun on the host scalar scan —
    // and (b) demonstrably exercise that path, visible as growth of the
    // process-wide `shard.backend.artifacts-stub.fallbacks` counter
    // (only stub-backend engines increment it, so the delta is ours).
    let mut cfg = host_config(ServingMode::Online, 512);
    cfg.shard_backend = ShardBackendKind::ArtifactsStub;
    let coord = Coordinator::start(&cfg).unwrap();
    let fallbacks = metrics::global().counter("shard.backend.artifacts-stub.fallbacks");
    let tiles = metrics::global().counter("shard.backend.artifacts-stub.tiles");
    let before = (fallbacks.get(), tiles.get());

    // Softmax through the stub: replies match the scalar reference.
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    let logits = rng.logits(vocab, 7.0);
    match coord.call(Payload::Softmax { logits: logits.clone() }, TIMEOUT).unwrap() {
        Reply::Softmax { probs } => {
            let mut want = vec![0.0; vocab];
            scalar::safe(&logits, &mut want);
            for (i, (a, b)) in probs.iter().zip(&want).enumerate() {
                assert!(close(*a, *b, 1e-4), "idx {i}: {a} vs {b}");
            }
        }
        other => panic!("unexpected {other:?}"),
    }

    // Decode through the stub: same token selection as the host-side
    // reference projection + Algorithm 4 (the fallback IS the scalar
    // scan, so even the selected indices are the reference's).
    let hidden = rng.logits(32, 1.0);
    match coord
        .call_opts(
            Payload::DecodeTopK { hidden: hidden.clone() },
            RequestOptions::with_k(5),
            TIMEOUT,
        )
        .unwrap()
    {
        Reply::TopK { vals, idx } => {
            let row = coord.executor().model().project_row(&hidden);
            let (want_vals, want_idx) = fused::online_topk(&row, 5);
            assert_eq!(idx, want_idx, "stub fallback must select the reference tokens");
            for (a, b) in vals.iter().zip(&want_vals) {
                assert!(close(*a, *b, 1e-3), "{a} vs {b}");
            }
        }
        other => panic!("unexpected {other:?}"),
    }

    let after = (fallbacks.get(), tiles.get());
    assert!(
        after.0 > before.0,
        "the stub must have declined tiles at runtime (fallbacks {} -> {})",
        before.0,
        after.0
    );
    assert!(after.1 > before.1, "stub tiles must be counted");
    coord.shutdown();
}

#[test]
fn host_shard_backends_agree_on_served_decodes() {
    // The same decode request served under every selectable backend
    // returns the same token selection; probabilities agree within fp
    // reassociation.  (The coordinator default is `auto` /
    // OSMAX_SHARD_BACKEND — this pins the full matrix regardless of
    // which leg CI is running.)
    let mut rng = Xoshiro256pp::seed_from_u64(33);
    let hidden = rng.logits(32, 1.0);
    let mut reference: Option<(Vec<f32>, Vec<i64>)> = None;
    for backend in ShardBackendKind::all() {
        let mut cfg = host_config(ServingMode::Online, 512);
        cfg.shard_backend = backend;
        let coord = Coordinator::start(&cfg).unwrap();
        let (vals, idx) = match coord
            .call_opts(
                Payload::DecodeTopK { hidden: hidden.clone() },
                RequestOptions::with_k(7),
                TIMEOUT,
            )
            .unwrap()
        {
            Reply::TopK { vals, idx } => (vals, idx),
            other => panic!("unexpected {other:?}"),
        };
        match &reference {
            None => reference = Some((vals, idx)),
            Some((want_vals, want_idx)) => {
                assert_eq!(&idx, want_idx, "backend {} selections", backend.as_str());
                for (a, b) in vals.iter().zip(want_vals) {
                    assert!(close(*a, *b, 1e-3), "backend {}: {a} vs {b}", backend.as_str());
                }
            }
        }
        coord.shutdown();
    }
}

#[test]
fn host_per_request_errors_do_not_poison_batch() {
    let coord = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    let vocab = coord.executor().vocab();
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let good = coord.submit(Payload::Softmax { logits: rng.logits(vocab, 3.0) }).unwrap();
    let bad = coord.submit(Payload::Softmax { logits: vec![1.0; 3] }).unwrap();
    assert!(good.recv_timeout(TIMEOUT).unwrap().is_ok());
    let err = bad.recv_timeout(TIMEOUT).unwrap().unwrap_err();
    assert!(err.to_string().contains("length"), "{err}");

    let err = coord
        .call_opts(
            Payload::DecodeTopK { hidden: vec![0.0; 32] },
            RequestOptions::with_k(10_000),
            TIMEOUT,
        )
        .unwrap_err();
    assert!(err.to_string().contains("k="), "{err}");
    coord.shutdown();
}

#[test]
fn host_all_invalid_batch_is_errors_not_a_panic() {
    // Regression: a formed batch in which EVERY request fails
    // validation leaves zero live rows.  The executor must
    // short-circuit before the chunked grid dispatch (`chunks(0)` /
    // zero-row grids) and still deliver a per-request error for each
    // member — for all three request classes.
    let mut cfg = host_config(ServingMode::Online, 512);
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(20); // coalesce into one batch
    let coord = Coordinator::start(&cfg).unwrap();

    // Softmax: every row has the wrong length → live set is empty.
    let rxs: Vec<_> = (0..5)
        .map(|i| coord.submit(Payload::Softmax { logits: vec![0.5; 3 + i] }).unwrap())
        .collect();
    for rx in rxs {
        let err = rx.recv_timeout(TIMEOUT).unwrap().unwrap_err();
        assert!(err.to_string().contains("length"), "{err}");
    }

    // Decode: every hidden state has the wrong width.
    let rxs: Vec<_> = (0..5)
        .map(|_| {
            let opts = RequestOptions::with_k(3);
            coord.submit_opts(Payload::DecodeTopK { hidden: vec![0.0; 7] }, opts).unwrap()
        })
        .collect();
    for rx in rxs {
        let err = rx.recv_timeout(TIMEOUT).unwrap().unwrap_err();
        assert!(err.to_string().contains("length"), "{err}");
    }

    // LmStep: every session id is unknown → the decode stage sees an
    // empty batch.
    let rxs: Vec<_> = (0..5u64)
        .map(|i| {
            coord
                .submit_opts(
                    Payload::LmStep { session: 777_000 + i, token: 1 },
                    RequestOptions::with_k(3),
                )
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let err = rx.recv_timeout(TIMEOUT).unwrap().unwrap_err();
        assert!(err.to_string().contains("unknown session"), "{err}");
    }

    // The coordinator survived all three empty-live batches.
    let mut rng = Xoshiro256pp::seed_from_u64(21);
    let logits = rng.logits(coord.executor().vocab(), 4.0);
    assert!(coord.call(Payload::Softmax { logits }, TIMEOUT).is_ok());
    coord.shutdown();
}

#[test]
fn host_lm_sessions_step_deterministically() {
    let coord = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    let s1 = coord.open_session();
    let s2 = coord.open_session();
    let r1 = coord
        .call_opts(Payload::LmStep { session: s1, token: 17 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    let r2 = coord
        .call_opts(Payload::LmStep { session: s2, token: 17 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    assert_eq!(r1, r2, "same token from same initial state → same distribution");
    // diverge the sessions
    let r1b = coord
        .call_opts(Payload::LmStep { session: s1, token: 3 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    let r2b = coord
        .call_opts(Payload::LmStep { session: s2, token: 9 }, RequestOptions::with_k(5), TIMEOUT)
        .unwrap();
    assert_ne!(r1b, r2b, "different tokens diverge the state");
    // unknown session errors
    let err = coord
        .call(Payload::LmStep { session: 999_999, token: 0 }, TIMEOUT)
        .unwrap_err();
    assert!(err.to_string().contains("unknown session"), "{err}");
    coord.shutdown();
}

#[test]
fn host_beam_search_runs_and_is_deterministic() {
    let coord = Coordinator::start(&host_config(ServingMode::Online, 512)).unwrap();
    let cfg = beam::BeamConfig { width: 3, steps: 4, k: 5, timeout: TIMEOUT };
    let beam1 = beam::beam_search(&coord, cfg, 7).unwrap();
    let tokens1: Vec<Vec<i32>> = beam1.iter().map(|h| h.tokens.clone()).collect();
    beam::release(&coord, &beam1);
    let beam2 = beam::beam_search(&coord, cfg, 7).unwrap();
    let tokens2: Vec<Vec<i32>> = beam2.iter().map(|h| h.tokens.clone()).collect();
    beam::release(&coord, &beam2);
    assert_eq!(tokens1, tokens2, "beam search is deterministic");
    assert_eq!(tokens1.len(), 3);
    assert!(tokens1.iter().all(|t| t.len() == 5), "start + 4 steps");
    coord.shutdown();
}

#[test]
fn host_server_full_protocol_over_tcp() {
    let mut cfg = host_config(ServingMode::Online, 512);
    cfg.addr = "127.0.0.1:0".into();
    let coordinator = Arc::new(Coordinator::start(&cfg).unwrap());
    let server = Server::bind(&cfg.addr, coordinator, 8).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let thread = std::thread::spawn(move || {
        let _ = server.serve();
    });

    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.get("metrics").is_some());

    // softmax over the wire
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let logits = rng.logits(2048, 6.0);
    let probs = client.softmax(&logits).unwrap();
    let sum: f32 = probs.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");

    // decode + sessions
    let hidden = rng.logits(32, 1.0);
    let (vals, idx) = client.decode(&hidden, Some(5)).unwrap();
    assert_eq!(vals.len(), 5);
    assert!(idx.iter().all(|&i| i >= 0 && (i as usize) < 2048));
    assert!(vals.windows(2).all(|w| w[0] >= w[1]));
    let sid = client.open_session().unwrap();
    let (v1, _) = client.lm_step(sid, 4, Some(3)).unwrap();
    assert_eq!(v1.len(), 3);
    client.close_session(sid).unwrap();

    // malformed input is an error, not a hang/disconnect
    assert!(client.softmax(&[1.0, 2.0]).is_err());
    client.ping().unwrap();

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = thread.join();
}
