//! Offline-vendored, dependency-free reimplementation of the `anyhow`
//! error-handling surface this workspace actually uses.
//!
//! The build container ships no crate registry, so the real `anyhow` is
//! unavailable; this crate provides an API-compatible subset:
//!
//! * [`Error`] — a boxed error with a context *chain*; `{e}` prints the
//!   outermost message, `{e:#}` prints the full `outer: inner: …` chain
//!   (matching anyhow's alternate formatting, which the test-suite
//!   asserts on).
//! * [`Result<T>`] — alias with the usual default error parameter.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction macros
//!   supporting format literals (with inline captures) and bare
//!   `Display` expressions.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both foreign error types and [`Error`] itself) and on `Option`.
//!
//! Unsupported (unused by this workspace): downcasting, backtraces,
//! `ensure!`.

use std::fmt;

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus an optional chain of underlying causes.
///
/// Like `anyhow::Error`, this type deliberately does **not** implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// conversion (which powers `?`) coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Build an error from a `std::error::Error`, preserving its
    /// `source()` chain as nested context.
    pub fn from_std(err: &(dyn std::error::Error + 'static)) -> Error {
        let mut chain: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(err);
        while let Some(e) = cur {
            chain.push(e.to_string());
            cur = e.source();
        }
        let mut built: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            built = Some(Error { msg, source: built.map(Box::new) });
        }
        built.expect("error chain has at least one element")
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}` — the full chain, anyhow-style.
            for (i, msg) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

/// `?`-conversion from any boxable standard error.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

/// `.context(..)` / `.with_context(..)` — anyhow's context extension.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

/// Context over `anyhow::Result` itself (chains another layer).
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

/// Context over `Option`: `None` becomes an error from the context.
impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an ad-hoc [`Error`].
///
/// `anyhow!("literal with {captures}")`, `anyhow!("fmt {}", args)`, and
/// `anyhow!(display_expr)` are all supported.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an ad-hoc error: `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("outer {}", 42);
        assert_eq!(format!("{e}"), "outer 42");
        let wrapped = e.context("while testing");
        assert_eq!(format!("{wrapped}"), "while testing");
        assert_eq!(format!("{wrapped:#}"), "while testing: outer 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("file missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("opening {}", "x.json")).unwrap_err();
        assert_eq!(format!("{e:#}"), "opening x.json: file missing");

        let o: Option<u32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn context_layers_stack() {
        fn inner() -> Result<()> {
            bail!("root cause")
        }
        let e = inner().context("mid").context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: root cause");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn bare_expression_form() {
        let s = String::from("stringly error");
        let e: Error = anyhow!(s);
        assert_eq!(format!("{e}"), "stringly error");
    }

    #[test]
    fn debug_shows_causes() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
