//! Offline stub of the `xla`/PJRT binding surface the runtime layer
//! compiles against.
//!
//! The build container has no crate registry and no `xla_extension`
//! shared library, so the real PJRT bindings cannot be built here.
//! This stub keeps the whole crate compiling and the non-runtime test
//! suite green:
//!
//! * [`Literal`] is **fully functional** on the host (construction,
//!   reshape, dtype/shape introspection, tuple unpacking) — the tensor
//!   interop code paths remain real.
//! * The PJRT client/executable types ([`PjRtClient`],
//!   [`PjRtLoadedExecutable`], [`PjRtBuffer`]) return
//!   [`Error::Unavailable`] from every entry point.  The engine layer
//!   already treats client construction failure as "drain commands with
//!   errors", and every artifact-dependent test skips when
//!   `artifacts/manifest.json` is absent, so the stub degrades to
//!   exactly the no-artifacts behaviour.
//!
//! Swapping in the real bindings is a one-line change in the root
//! `Cargo.toml` (point the `xla` dependency at the real crate); no
//! source edits are required because the API below mirrors it.

use std::fmt;

/// Stub error type (mirrors `xla::Error` closely enough for callers).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// PJRT functionality invoked in a build without the PJRT backend.
    Unavailable(&'static str),
    /// Host-side literal misuse (shape mismatch, wrong dtype, ...).
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT backend unavailable (offline stub build; \
                 link the real xla bindings to execute artifacts)"
            ),
            Error::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the manifest layer understands (plus a spare so the
/// caller's `other =>` match arms stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
    F64,
}

/// Dense array shape: dimensions plus element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn store(data: &[Self]) -> Payload;
    fn load(payload: &Payload) -> Option<Vec<Self>>;
}

/// Host storage behind a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn store(data: &[f32]) -> Payload {
        Payload::F32(data.to_vec())
    }

    fn load(payload: &Payload) -> Option<Vec<f32>> {
        match payload {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn store(data: &[i32]) -> Payload {
        Payload::I32(data.to_vec())
    }

    fn load(payload: &Payload) -> Option<Vec<i32>> {
        match payload {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-resident literal value — fully functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: T::store(data) }
    }

    /// Tuple literal (what a multi-output executable returns).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], payload: Payload::Tuple(parts) }
    }

    fn element_count(&self) -> i64 {
        match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
            Payload::Tuple(v) => v.len() as i64,
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error::Invalid("cannot reshape a tuple literal".into()));
        }
        let want: i64 = dims.iter().product();
        if want != self.element_count() {
            return Err(Error::Invalid(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.element_count()
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Shape of a dense (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => {
                return Err(Error::Invalid("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy out the host data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.payload)
            .ok_or_else(|| Error::Invalid("literal dtype mismatch in to_vec".into()))
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error::Invalid("literal is not a tuple".into())),
        }
    }
}

/// PJRT device buffer — opaque and unconstructible in the stub.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT compiled executable — stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client — construction fails in the stub, which the engine layer
/// converts into per-command errors (or, in practice, never reaches:
/// artifact-gated code paths skip when no artifacts are built).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_literal"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module — stub (the text parser lives in xla_extension).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper — stub.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32_and_tuple() {
        let a = Literal::vec1(&[7i32, 8]);
        let b = Literal::vec1(&[0.5f32]);
        let t = Literal::tuple(vec![a.clone(), b.clone()]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
        assert!(a.to_tuple().is_err());
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn reshape_validates_element_count() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn pjrt_surface_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
