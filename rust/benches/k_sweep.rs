//! `cargo bench --bench k_sweep` — regenerates the paper's k_sweep series.
//! Thin wrapper over [`onlinesoftmax::benches::k_sweep`]; options via env:
//! OSMAX_BENCH_FAST=1 for a quick pass.
fn main() {
    let opts = onlinesoftmax::benches::BenchOpts {
        threads: 1,
        json_out: std::env::var("OSMAX_BENCH_JSON").ok(),
        ..Default::default()
    };
    onlinesoftmax::benches::k_sweep(&opts).expect("bench failed");
}
