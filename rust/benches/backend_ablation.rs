//! `cargo bench --bench backend_ablation` — scalar (fused blocked) vs
//! vectorized (lane-split streaming) vs twopass (stored-partials)
//! shard-scan backends across vocab sizes — the crossover measurement
//! behind `auto` routing.  Thin wrapper over
//! [`onlinesoftmax::benches::backend_ablation`]; options via env:
//! OSMAX_BENCH_FAST=1 for a quick pass, OSMAX_BENCH_THREADS=N to pin
//! the shard-worker count (default 0 = one worker per core),
//! OSMAX_BENCH_BATCH=B to set the batch rows (default 8).
fn main() {
    let threads = std::env::var("OSMAX_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let batch = std::env::var("OSMAX_BENCH_BATCH").ok().and_then(|s| s.parse().ok());
    let opts = onlinesoftmax::benches::BenchOpts {
        threads,
        batch,
        json_out: std::env::var("OSMAX_BENCH_JSON").ok(),
        ..Default::default()
    };
    onlinesoftmax::benches::backend_ablation(&opts).expect("bench failed");
}
