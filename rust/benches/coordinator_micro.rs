//! Coordinator micro-benchmarks: the L3 hot-path pieces the paper's
//! system layer adds on top of the kernels — batch formation, the ⊕
//! shard merge, and top-k buffer merging.  These quantify that the
//! coordinator is NOT the bottleneck (DESIGN.md §Perf: L3 target).

use onlinesoftmax::benchkit::{bench, black_box, fmt_time, BenchConfig, Table};
use onlinesoftmax::coordinator::{BatchPolicy, Batcher, Payload, Request};
use onlinesoftmax::exec::oneshot;
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::softmax::monoid::MD;
use onlinesoftmax::topk::{scan_topk, TopKBuffer};
use std::time::Duration;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut table = Table::new(&["operation", "median", "per-item"]);

    // ⊕ merge of shard partials: 64 shards × 16 rows.
    {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let parts: Vec<Vec<MD>> = (0..64)
            .map(|_| {
                (0..16)
                    .map(|_| MD { m: rng.next_normal() * 10.0, d: rng.range_f32(1.0, 100.0) })
                    .collect()
            })
            .collect();
        let s = bench(&cfg, || {
            let mut acc = vec![MD::IDENTITY; 16];
            for part in &parts {
                for (a, p) in acc.iter_mut().zip(part) {
                    *a = a.combine(*p);
                }
            }
            black_box(acc[0].d)
        });
        table.row(vec![
            "⊕ merge 64 shards × 16 rows".into(),
            fmt_time(s.median),
            fmt_time(s.median / (64.0 * 16.0)),
        ]);
    }

    // top-k buffer merge: 64 shards × k=5.
    {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let bufs: Vec<TopKBuffer> = (0..64)
            .map(|s| {
                let x = rng.logits(128, 5.0);
                scan_topk(&x, 5, (s * 128) as i64)
            })
            .collect();
        let s = bench(&cfg, || {
            let mut acc = TopKBuffer::new(5);
            for b in &bufs {
                acc.merge(b);
            }
            black_box(acc.values()[0])
        });
        table.row(vec![
            "topk merge 64 shards (k=5)".into(),
            fmt_time(s.median),
            fmt_time(s.median / 64.0),
        ]);
    }

    // batcher submit→drain round trip at batch 16.
    {
        let batcher = Batcher::new(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_secs(1),
            queue_capacity: 1024,
            ..BatchPolicy::default()
        });
        let s = bench(&cfg, || {
            for i in 0..16u64 {
                let (tx, _rx) = oneshot();
                batcher
                    .submit(Request::new(i, Payload::Softmax { logits: Vec::new() }, tx))
                    .ok();
            }
            let (_, batch, _) = batcher.next_batch().unwrap();
            black_box(batch.len())
        });
        table.row(vec![
            "batcher 16-submit + drain".into(),
            fmt_time(s.median),
            fmt_time(s.median / 16.0),
        ]);
    }

    println!("\n=== coordinator micro-benchmarks ===");
    println!("{}", table.render());
}
