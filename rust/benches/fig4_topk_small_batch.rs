//! `cargo bench --bench fig4_topk_small_batch` — regenerates the paper's fig4 series.
//! Thin wrapper over [`onlinesoftmax::benches::fig4`]; options via env:
//! OSMAX_BENCH_FAST=1 for a quick pass.
fn main() {
    let opts = onlinesoftmax::benches::BenchOpts {
        threads: 1,
        json_out: std::env::var("OSMAX_BENCH_JSON").ok(),
        ..Default::default()
    };
    onlinesoftmax::benches::fig4(&opts).expect("bench failed");
}
