//! `cargo bench --bench fig3_topk_large_batch` — regenerates the paper's fig3 series.
//! Thin wrapper over [`onlinesoftmax::benches::fig3`]; options via env:
//! OSMAX_BENCH_FAST=1 for a quick pass.
fn main() {
    let opts = onlinesoftmax::benches::BenchOpts {
        threads: 1,
        json_out: std::env::var("OSMAX_BENCH_JSON").ok(),
        ..Default::default()
    };
    onlinesoftmax::benches::fig3(&opts).expect("bench failed");
}
