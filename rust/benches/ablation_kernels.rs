//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. scalar vs lane-parallel (vectorized) online normalizer — how much
//!    of the speedup comes from keeping the single-pass loop
//!    vectorized (§7 of the paper);
//! 2. std `expf` vs the branchless [`fast_exp`] — the CPU stand-in for
//!    the GPU SFU;
//! 3. thread scaling of the parallel ⊕ reduction (§3.1);
//! 4. insertion-buffer vs heap top-k at several K.

use onlinesoftmax::benchkit::{bench, black_box, fmt_time, BenchConfig, Table};
use onlinesoftmax::rng::Xoshiro256pp;
use onlinesoftmax::softmax::{fastexp::fast_exp, monoid::MD, parallel, scalar, vectorized};
use onlinesoftmax::topk;

fn main() {
    let cfg = BenchConfig::from_env();
    let v = 262_144; // 1 MB rows: out of L1/L2, comfortably in bench time
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let x = rng.logits(v, 6.0);

    println!("\n=== ablation: scalar vs vectorized vs multithreaded normalizer (V={v}) ===");
    let mut t = Table::new(&["variant", "median", "elems/s"]);
    let s_scalar = bench(&cfg, || black_box(scalar::online_normalizer(&x)));
    let s_vec = bench(&cfg, || black_box(vectorized::online_normalizer(&x)));
    t.row(vec![
        "scalar (Alg 3 verbatim)".into(),
        fmt_time(s_scalar.median),
        format!("{:.0}M", s_scalar.elements_per_sec(v as f64) / 1e6),
    ]);
    t.row(vec![
        "lane-parallel (16 lanes ⊕)".into(),
        fmt_time(s_vec.median),
        format!("{:.0}M", s_vec.elements_per_sec(v as f64) / 1e6),
    ]);
    for threads in [2, 4, 8] {
        let s = bench(&cfg, || black_box(parallel::online_normalizer(&x, threads)));
        t.row(vec![
            format!("threads ⊕ x{threads}"),
            fmt_time(s.median),
            format!("{:.0}M", s.elements_per_sec(v as f64) / 1e6),
        ]);
    }
    println!("{}", t.render());

    println!("=== ablation: exp implementations (normalizer inner loop) ===");
    let mut t = Table::new(&["exp", "median", "elems/s"]);
    let s_std = bench(&cfg, || {
        let mut md = MD::IDENTITY;
        for &xi in &x {
            md = md.push(xi); // std expf path
        }
        black_box(md.d)
    });
    let s_fast = bench(&cfg, || {
        let mut m = f32::NEG_INFINITY;
        let mut d = 0.0f32;
        for &xi in &x {
            let m2 = m.max(xi);
            d = d * fast_exp(m - m2) + fast_exp(xi - m2);
            m = m2;
        }
        black_box(d)
    });
    t.row(vec![
        "std expf (scalar)".into(),
        fmt_time(s_std.median),
        format!("{:.0}M", s_std.elements_per_sec(v as f64) / 1e6),
    ]);
    t.row(vec![
        "fast_exp (branchless)".into(),
        fmt_time(s_fast.median),
        format!("{:.0}M", s_fast.elements_per_sec(v as f64) / 1e6),
    ]);
    println!("{}", t.render());

    println!("=== ablation: insertion buffer vs heap top-k (V={v}) ===");
    let mut t = Table::new(&["K", "insertion buffer", "heap"]);
    for k in [1usize, 5, 15, 30, 100] {
        let s_buf = bench(&cfg, || black_box(topk::scan_topk(&x, k, 0).values()[0]));
        let s_heap = bench(&cfg, || black_box(topk::heap_topk(&x, k).0[0]));
        t.row(vec![k.to_string(), fmt_time(s_buf.median), fmt_time(s_heap.median)]);
    }
    println!("{}", t.render());
}
