//! `cargo bench --bench shard_ablation` — sharded fused softmax+topk vs
//! the single-thread fused kernel vs the unfused baseline.
//! Thin wrapper over [`onlinesoftmax::benches::shard_ablation`]; options
//! via env: OSMAX_BENCH_FAST=1 for a quick pass, OSMAX_BENCH_THREADS=N
//! to pin the shard-worker count (default 0 = one worker per core).
fn main() {
    let threads = std::env::var("OSMAX_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let opts = onlinesoftmax::benches::BenchOpts {
        threads,
        json_out: std::env::var("OSMAX_BENCH_JSON").ok(),
        ..Default::default()
    };
    onlinesoftmax::benches::shard_ablation(&opts).expect("bench failed");
}
