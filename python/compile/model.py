"""L2 — the JAX decode-step graphs the rust coordinator serves.

The paper's motivating workload (§1, §4) is the output end of an
auto-regressive language model: a projection layer mapping the hidden
state into vocabulary space, followed by Softmax (training / scoring)
or Softmax+TopK (beam-search inference).  This module defines every
graph the serving system executes, in two flavours:

* ``*_jnp``    — straight-line jnp (XLA fuses it); the production
  serving path lowered to HLO by :mod:`compile.aot`.
* ``*_pallas`` — the same graph but routed through the L1 Pallas
  kernels, used for cross-validation and for the kernel-integration
  artifact the rust test-suite executes.

Sharded serving: :func:`decode_partial` computes, for one vocabulary
shard, the tuple ``(m, d, u, p)`` — partial online normalizer (lines
1-6 of Algorithm 3) plus shard-local top-k candidates.  The rust
coordinator merges shards with the ⊕ operator (eq. 4) and finalizes
``v = e^{u − m}/d``; that merge is exactly §3.1's parallel online
normalizer calculation, promoted to the distributed layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fused_topk, online, ref, safe


# ---------------------------------------------------------------------------
# Projection layer (the paper's "projects hidden representation into the
# output vocabulary space").
# ---------------------------------------------------------------------------

def project(h: jax.Array, w: jax.Array) -> jax.Array:
    """``logits = h · Wᵀ``;  h: (B, H), w: (V, H) → (B, V)."""
    return jnp.dot(h, w.T, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Full-vocabulary decode steps (single executable owns the whole vocab).
# ---------------------------------------------------------------------------

def softmax_safe_jnp(x: jax.Array) -> tuple[jax.Array]:
    """Logits → probabilities, Algorithm 2 semantics (serving default)."""
    return (ref.softmax_safe(x),)


def softmax_online_pallas(x: jax.Array) -> tuple[jax.Array]:
    """Logits → probabilities through the L1 online-softmax kernel."""
    return (online.softmax(x),)


def decode_topk_jnp(h: jax.Array, w: jax.Array, *, k: int) -> tuple[jax.Array, jax.Array]:
    """Projection → safe softmax → top-k (the unfused baseline path)."""
    logits = project(h, w)
    return ref.softmax_topk(logits, k)


def decode_topk_online_jnp(h: jax.Array, w: jax.Array, *, k: int) -> tuple[jax.Array, jax.Array]:
    """Projection → online-normalizer top-k, expressed in jnp.

    Semantically Algorithm 4: the normalizer and the top-k are both
    single-reduction consumers of the logits, so XLA can fuse them into
    one sweep — the jnp rendering of the paper's fused kernel.
    """
    logits = project(h, w)
    m, d = ref.online_normalizer(logits)
    u, p = ref.topk(logits, k)
    v = jnp.exp(u - m[:, None]) / d[:, None]
    return v, p


def decode_topk_pallas(h: jax.Array, w: jax.Array, *, k: int) -> tuple[jax.Array, jax.Array]:
    """Projection → the L1 fused online-softmax+topk kernel (Algorithm 4)."""
    logits = project(h, w)
    return fused_topk.online_fused(logits, k)


# ---------------------------------------------------------------------------
# Vocabulary-sharded decode: per-shard partials merged by the rust L3.
# ---------------------------------------------------------------------------

def decode_partial_jnp(
    h: jax.Array, w_shard: jax.Array, *, k: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One vocab shard's ``(m, d, u, p_local)`` — jnp fast path.

    ``p_local`` indexes into the shard; the coordinator adds the shard's
    vocabulary offset before the global ⊕/top-k merge.
    """
    logits = project(h, w_shard)
    m, d = ref.online_normalizer(logits)
    u, p = ref.topk(logits, k)
    return m, d, u, p


def decode_partial_pallas(
    h: jax.Array, w_shard: jax.Array, *, k: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Shard partial through the single-pass L1 kernel (Algorithm 4 core)."""
    logits = project(h, w_shard)
    return fused_topk.online_fused_raw(logits, k)


def softmax_partial_jnp(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Shard partial for plain softmax serving: just ``(m, d)`` (Alg 3 1-6)."""
    return ref.online_normalizer(x)


def softmax_scale_jnp(x: jax.Array, m: jax.Array, d: jax.Array) -> tuple[jax.Array]:
    """Second pass for sharded softmax: ``y = e^{x − m} / d`` given the
    globally ⊕-merged ``(m, d)`` from the coordinator."""
    xf = x.astype(jnp.float32)
    return ((jnp.exp(xf - m[:, None]) / d[:, None]).astype(x.dtype),)


# ---------------------------------------------------------------------------
# Tiny LM used by the end-to-end example: an embedding + GRU-free
# feed-forward state update, enough to drive realistic beam search
# without a training framework.  Deterministic given the seed weights.
# ---------------------------------------------------------------------------

def toy_lm_step(
    emb: jax.Array,  # (V, H) token embeddings
    w1: jax.Array,   # (H, H)
    w2: jax.Array,   # (H, H)
    state: jax.Array,  # (B, H)
    token: jax.Array,  # (B,) int32
) -> tuple[jax.Array]:
    """One recurrent state update: ``s' = tanh(s·W1 + E[token]·W2)``."""
    e = jnp.take(emb, token, axis=0)
    new = jnp.tanh(jnp.dot(state, w1) + jnp.dot(e, w2))
    return (new,)
