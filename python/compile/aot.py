"""AOT lowering: JAX decode graphs → HLO *text* artifacts for the rust runtime.

``python -m compile.aot --out-dir ../artifacts`` lowers every serving
variant in :mod:`compile.model` for the configured shape buckets and
writes:

* ``artifacts/<name>.hlo.txt``  — one HLO-text module per executable,
* ``artifacts/manifest.json``   — machine-readable index (shapes,
  dtypes, variant metadata) consumed by ``rust/src/runtime/manifest.rs``.

HLO **text** is the interchange format, not ``lowered.compile()`` /
serialized protos: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here, at build time.  The rust binary is self-contained
once ``artifacts/`` exists; ``make artifacts`` is a no-op when inputs
are unchanged.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Shape configuration.  Batch buckets mirror the coordinator's padding
# policy (rust/src/coordinator/batcher.rs): requests are padded up to the
# next bucket so a small, fixed set of executables covers all loads.
# ---------------------------------------------------------------------------

DEFAULT_BATCH_BUCKETS = (1, 4, 16)
DEFAULT_VOCAB = 8192
DEFAULT_HIDDEN = 128
DEFAULT_K = 5
DEFAULT_SHARDS = 4


def _f32(*dims: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def _i32(*dims: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(dims), jnp.int32)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


# ---------------------------------------------------------------------------
# Artifact catalogue.
# ---------------------------------------------------------------------------

def build_catalogue(
    batches=DEFAULT_BATCH_BUCKETS,
    vocab=DEFAULT_VOCAB,
    hidden=DEFAULT_HIDDEN,
    k=DEFAULT_K,
    shards=DEFAULT_SHARDS,
    with_pallas=True,
):
    """Return ``[(name, fn, example_args, meta), ...]`` for every artifact.

    ``meta`` flows verbatim into the manifest; the rust side keys its
    executable registry on (variant, batch) and validates shard layout
    against (vocab, shard_count).
    """
    if vocab % shards:
        raise ValueError(f"vocab={vocab} must divide into shards={shards}")
    vs = vocab // shards
    cat = []

    for b in batches:
        # Full-vocab softmax serving (Figures 1-2 workload).
        cat.append((
            f"softmax_safe_b{b}_v{vocab}",
            model.softmax_safe_jnp,
            (_f32(b, vocab),),
            dict(variant="softmax_safe", batch=b, vocab=vocab),
        ))
        # Sharded softmax: per-shard partial (m, d) + second-pass scale.
        cat.append((
            f"softmax_partial_b{b}_v{vs}",
            model.softmax_partial_jnp,
            (_f32(b, vs),),
            dict(variant="softmax_partial", batch=b, vocab=vs),
        ))
        cat.append((
            f"softmax_scale_b{b}_v{vs}",
            model.softmax_scale_jnp,
            (_f32(b, vs), _f32(b), _f32(b)),
            dict(variant="softmax_scale", batch=b, vocab=vs),
        ))
        # Beam-search decode: projection + softmax + top-k (Figures 3-4).
        cat.append((
            f"decode_topk_b{b}_h{hidden}_v{vocab}_k{k}",
            functools.partial(model.decode_topk_jnp, k=k),
            (_f32(b, hidden), _f32(vocab, hidden)),
            dict(variant="decode_topk_safe", batch=b, vocab=vocab, hidden=hidden, k=k),
        ))
        cat.append((
            f"decode_topk_online_b{b}_h{hidden}_v{vocab}_k{k}",
            functools.partial(model.decode_topk_online_jnp, k=k),
            (_f32(b, hidden), _f32(vocab, hidden)),
            dict(variant="decode_topk_online", batch=b, vocab=vocab, hidden=hidden, k=k),
        ))
        # Sharded decode partial: the ⊕-mergeable unit of §3.1.
        cat.append((
            f"decode_partial_b{b}_h{hidden}_vs{vs}_k{k}",
            functools.partial(model.decode_partial_jnp, k=k),
            (_f32(b, hidden), _f32(vs, hidden)),
            dict(variant="decode_partial", batch=b, vocab=vs, hidden=hidden, k=k,
                 shard_count=shards, full_vocab=vocab),
        ))
        # Toy-LM recurrent state update for the end-to-end example.
        cat.append((
            f"lm_step_b{b}_h{hidden}_v{vocab}",
            model.toy_lm_step,
            (_f32(vocab, hidden), _f32(hidden, hidden), _f32(hidden, hidden),
             _f32(b, hidden), _i32(b)),
            dict(variant="lm_step", batch=b, vocab=vocab, hidden=hidden),
        ))

    if with_pallas:
        # Kernel-integration artifacts: the L1 Pallas kernels lowered
        # (interpret mode) into self-contained HLO, executed by the rust
        # integration tests to prove the full L1→L3 path composes.
        # Small shapes: interpret-mode HLO is while-loop heavy.
        pb, pv, ph, pk = 2, 1024, 64, 5
        cat.append((
            f"softmax_online_pallas_b{pb}_v{pv}",
            model.softmax_online_pallas,
            (_f32(pb, pv),),
            dict(variant="softmax_online_pallas", batch=pb, vocab=pv),
        ))
        cat.append((
            f"decode_topk_pallas_b{pb}_h{ph}_v{pv}_k{pk}",
            functools.partial(model.decode_topk_pallas, k=pk),
            (_f32(pb, ph), _f32(pv, ph)),
            dict(variant="decode_topk_pallas", batch=pb, vocab=pv, hidden=ph, k=pk),
        ))
    return cat


def _spec_json(args) -> list[dict]:
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
    ]


def _out_spec(fn, example_args) -> list[dict]:
    outs = jax.eval_shape(fn, *example_args)
    leaves = jax.tree_util.tree_leaves(outs)
    return [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in leaves]


def write_artifacts(out_dir: str, catalogue, *, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "artifacts": []}
    for name, fn, args, meta in catalogue:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_entry(fn, args)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update(
            name=name,
            file=f"{name}.hlo.txt",
            inputs=_spec_json(args),
            outputs=_out_spec(fn, args),
            sha256=hashlib.sha256(text.encode()).hexdigest(),
        )
        manifest["artifacts"].append(entry)
        if verbose:
            print(f"  {name}: {len(text)} chars", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file makefile hook; "
                    "writes the full artifact set into its directory")
    ap.add_argument("--vocab", type=int, default=DEFAULT_VOCAB)
    ap.add_argument("--hidden", type=int, default=DEFAULT_HIDDEN)
    ap.add_argument("--k", type=int, default=DEFAULT_K)
    ap.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--batches", type=int, nargs="+", default=list(DEFAULT_BATCH_BUCKETS))
    ap.add_argument("--no-pallas", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    cat = build_catalogue(
        batches=tuple(args.batches), vocab=args.vocab, hidden=args.hidden,
        k=args.k, shards=args.shards, with_pallas=not args.no_pallas,
    )
    manifest = write_artifacts(out_dir, cat)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}", file=sys.stderr)
    if args.out:
        # Makefile stamp: ensure the named sentinel exists.
        if not os.path.exists(args.out):
            with open(args.out, "w") as f:
                f.write("# see manifest.json\n")


if __name__ == "__main__":
    main()
