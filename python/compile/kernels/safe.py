"""Algorithm 2 — safe (max-subtracted) softmax as a Pallas kernel triple.

This is the formulation every major framework ships, and the baseline
the paper's Online softmax improves on.  Three passes over the input
(4 memory accesses / element):

* pass 1: ``m = max_j x_j``            (1 load / element)
* pass 2: ``d = Σ_j e^{x_j − m}``      (1 load / element)
* pass 3: ``y_i = e^{x_i − m} / d``    (1 load + 1 store / element)

Each pass is its own ``pallas_call`` so the HBM traffic of the lowered
module matches the algorithm's access count — the quantity the paper's
evaluation is about.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _max_kernel(x_ref, m_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)

    xb = common.as_f32(x_ref[...])
    m_ref[...] = jnp.maximum(m_ref[...], jnp.max(xb, axis=-1))


def _sum_kernel(x_ref, m_ref, d_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)

    xb = common.as_f32(x_ref[...])
    d_ref[...] += jnp.sum(jnp.exp(xb - m_ref[...][:, None]), axis=-1)


def _scale_kernel(x_ref, m_ref, d_ref, y_ref):
    xb = common.as_f32(x_ref[...])
    y = jnp.exp(xb - m_ref[...][:, None]) / d_ref[...][:, None]
    y_ref[...] = y.astype(y_ref.dtype)


def rowmax(x: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Pass 1: per-row maximum (lines 1-4 of Algorithm 2)."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    return common.kernel_call(
        _max_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((b, bv), lambda j: (0, j))],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
    )(xp)


def normalizer(x: jax.Array, *, block_v: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Passes 1-2: ``(m, d)`` with two full sweeps over ``x``."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    m = rowmax(x, block_v=bv)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    d = common.kernel_call(
        _sum_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
    )(xp, m)
    return m, d


def softmax(x: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Full Algorithm 2 over the last axis of ``(B, V)``."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    m, d = normalizer(x, block_v=bv)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    yp = common.kernel_call(
        _scale_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, bv), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
    )(xp, m, d)
    return yp[:, :v]
