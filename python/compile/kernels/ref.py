"""Pure-jnp reference oracles for the softmax / top-k kernels.

These are the *correctness ground truth* for every Pallas kernel in this
package and for the rust implementations (via golden files emitted by
``python -m compile.golden``).  They intentionally mirror the paper's
algorithm definitions:

* :func:`softmax_naive`   — Algorithm 1 (no max subtraction, 2 passes).
* :func:`softmax_safe`    — Algorithm 2 (max-subtracted, 3 passes) — the
  formulation used by every major DL framework.
* :func:`online_normalizer` — lines 1-6 of Algorithm 3 expressed as a
  vectorized computation (the quantity the online kernel must produce).
* :func:`md_combine`      — the ⊕ operator from eq. (4) of the paper.
* :func:`softmax_topk`    — Softmax followed by TopK, eq. (5).

Everything here is straight-line ``jnp``: XLA sees the whole graph and
is free to fuse, so these also serve as the *fast serving path* lowered
by ``compile.aot`` (see DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "softmax_naive",
    "softmax_safe",
    "online_normalizer",
    "md_combine",
    "md_identity",
    "softmax_topk",
    "topk",
]


def softmax_naive(x: jax.Array) -> jax.Array:
    """Algorithm 1: softmax without max subtraction.

    Overflows for inputs ≳ 88.7 (fp32); kept as the numerical baseline
    the paper compares against.  Rows are the last axis.
    """
    e = jnp.exp(x.astype(jnp.float32))
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def softmax_safe(x: jax.Array) -> jax.Array:
    """Algorithm 2: the standard max-subtracted ("safe") softmax."""
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def online_normalizer(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference for lines 1-6 of Algorithm 3.

    Returns ``(m, d)`` with ``m = max_j x_j`` and
    ``d = Σ_j e^{x_j − m}`` over the last axis.  The online kernel must
    produce exactly this pair (up to fp associativity).
    """
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1)
    d = jnp.sum(jnp.exp(xf - m[..., None]), axis=-1)
    return m, d


def md_identity(shape=(), dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """Identity element of the ⊕ monoid: ``(−∞, 0)``."""
    return (jnp.full(shape, -jnp.inf, dtype), jnp.zeros(shape, dtype))


def md_combine(
    a: tuple[jax.Array, jax.Array], b: tuple[jax.Array, jax.Array]
) -> tuple[jax.Array, jax.Array]:
    """The ⊕ operator, eq. (4): merge two partial (m, d) normalizer pairs.

    Associative and commutative; ``md_identity()`` is its identity.
    ``jnp.where`` guards the ``−∞ − −∞ = nan`` corner when one side is
    the identity element.
    """
    m_a, d_a = a
    m_b, d_b = b
    m = jnp.maximum(m_a, m_b)
    # e^{−∞ − −∞} must act as 0-weighted, not nan:
    scale_a = jnp.where(jnp.isneginf(m_a) & jnp.isneginf(m), 0.0, jnp.exp(m_a - m))
    scale_b = jnp.where(jnp.isneginf(m_b) & jnp.isneginf(m), 0.0, jnp.exp(m_b - m))
    return m, d_a * scale_a + d_b * scale_b


def topk(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Eq. (5): values and int32 indices of the k largest entries.

    Implemented with a stable argsort rather than ``jax.lax.top_k``:
    the modern lowering of ``top_k`` emits an HLO ``topk(..., largest)``
    custom op that xla_extension 0.5.1's text parser rejects, while
    ``sort`` round-trips cleanly (see DESIGN.md §Hardware-Adaptation).
    Stable sort ⇒ ties resolve to the lowest index, matching both
    ``lax.top_k`` and the rust ``TopKBuffer``.
    """
    idx = jnp.argsort(-x, axis=-1, stable=True)[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def softmax_topk(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Safe softmax followed by TopK — the unfused baseline of §4.

    Returns ``(v, z)``: the k largest *probabilities* and their indices.
    """
    y = softmax_safe(x)
    return topk(y, k)
