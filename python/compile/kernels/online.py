"""Algorithm 3 — Online softmax: the paper's core contribution.

Pass 1 (:func:`normalizer`) computes **both** the running maximum ``m``
and the normalizer ``d = Σ e^{x_j − m}`` in a *single sweep* over the
vocabulary blocks.  Whenever a new block raises the maximum, the carried
normalizer is rescaled by ``e^{m_old − m_new}`` — line 5 of Algorithm 3,
applied at tile granularity via the ⊕ operator of eq. (4):

    (m, d) ← (m, d) ⊕ (max(block), Σ e^{block − max(block)})

Tile-level ⊕ is exactly the "parallel online normalizer" of §3.1; on a
real TPU each grid step streams one HBM tile into VMEM while the carry
pair lives in registers/VMEM scratch.  Total traffic: 1 load / element
for the normalizer, 3 loads+stores / element for the full softmax —
versus 4 for Algorithm 2.

Pass 2 (:func:`softmax`) is the unavoidable ``y_i = e^{x_i − m} / d``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _online_normalizer_kernel(x_ref, m_ref, d_ref):
    """Single-pass fused max+normalizer with an ⊕-carry across the grid.

    Grid: ``(num_v_blocks,)``.  ``m_ref``/``d_ref`` are both outputs and
    carries: every grid step reads the running pair, folds in one block,
    and writes it back.  Equivalent to lines 1-6 of Algorithm 3 with the
    loop blocked by ``block_v``.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)

    xb = common.as_f32(x_ref[...])

    # Block-local (m, d): a vectorized leaf of the ⊕ reduction tree.
    m_blk = jnp.max(xb, axis=-1)
    d_blk = jnp.sum(jnp.exp(xb - m_blk[:, None]), axis=-1)

    # ⊕-combine with the carry (eq. 4).  m_old = −∞ on the first block;
    # e^{−∞ − m_new} = 0 multiplied by d_old = 0 is exactly the identity
    # fold, so no special-casing is needed as long as each block holds at
    # least one finite element (guaranteed: padding is −∞ but blocks are
    # never entirely padding — see common.pad_vocab).
    m_old = m_ref[...]
    d_old = d_ref[...]
    m_new = jnp.maximum(m_old, m_blk)
    scale_old = jnp.where(jnp.isneginf(m_old), 0.0, jnp.exp(m_old - m_new))
    d_ref[...] = d_old * scale_old + d_blk * jnp.exp(m_blk - m_new)
    m_ref[...] = m_new


def _scale_kernel(x_ref, m_ref, d_ref, y_ref):
    xb = common.as_f32(x_ref[...])
    y = jnp.exp(xb - m_ref[...][:, None]) / d_ref[...][:, None]
    y_ref[...] = y.astype(y_ref.dtype)


def normalizer(x: jax.Array, *, block_v: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Lines 1-6 of Algorithm 3: ``(m, d)`` in one pass over ``x``."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    m, d = common.kernel_call(
        _online_normalizer_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((b, bv), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
    )(xp)
    return m, d


def softmax(x: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Full Algorithm 3 over the last axis of ``(B, V)``.

    One normalizer sweep + one scale sweep = 3 accesses / element.
    """
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    m, d = normalizer(x, block_v=bv)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    yp = common.kernel_call(
        _scale_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, bv), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
    )(xp, m, d)
    return yp[:, :v]
