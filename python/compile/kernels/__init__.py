"""L1 — Pallas kernels for the paper's softmax algorithms.

Modules map one-to-one onto the paper:

* :mod:`.naive`      — Algorithm 1 (2-pass, numerically unsafe baseline)
* :mod:`.safe`       — Algorithm 2 (3-pass, the framework default)
* :mod:`.online`     — Algorithm 3 (single-pass online normalizer)
* :mod:`.fused_topk` — Algorithm 4 (online softmax ⊕ running top-k) and
  the safe-fused baseline
* :mod:`.ref`        — pure-jnp oracles used by pytest and by the fast
  serving path lowered in :mod:`compile.aot`

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); block structure is still authored for the TPU memory
hierarchy — see DESIGN.md §Hardware-Adaptation.
"""

from . import common, fused_topk, naive, online, ref, safe  # noqa: F401

__all__ = ["common", "naive", "safe", "online", "fused_topk", "ref"]
