"""Algorithm 1 — naive softmax as a Pallas kernel pair.

Two passes over the input (3 memory accesses / element):

* pass 1 (:func:`_normalizer_kernel`): accumulate ``d = Σ e^{x_j}``
  block-by-block, carrying ``d`` in a VMEM carry output across the grid,
* pass 2 (:func:`_scale_kernel`): ``y_i = e^{x_i} / d``.

Not numerically safe — ``e^{x}`` overflows fp32 for x ≳ 88.7 — but it is
the paper's performance baseline (its access pattern matches Online
softmax, which is the point of Figure 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _normalizer_kernel(x_ref, d_ref):
    """Grid: (num_v_blocks,).  Carries the running Σ e^{x} in d_ref."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)

    xb = common.as_f32(x_ref[...])
    d_ref[...] += jnp.sum(jnp.exp(xb), axis=-1)


def _scale_kernel(x_ref, d_ref, y_ref):
    xb = common.as_f32(x_ref[...])
    y = jnp.exp(xb) / d_ref[...][:, None]
    y_ref[...] = y.astype(y_ref.dtype)


def normalizer(x: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Pass 1 of Algorithm 1: ``d = Σ_j e^{x_j}`` per row."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    return common.kernel_call(
        _normalizer_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((b, bv), lambda j: (0, j))],
        out_specs=pl.BlockSpec((b,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
    )(xp)


def softmax(x: jax.Array, *, block_v: int | None = None) -> jax.Array:
    """Full Algorithm 1: naive softmax over the last axis of ``(B, V)``."""
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    d = normalizer(x, block_v=bv)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)
    yp = common.kernel_call(
        _scale_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, bv), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
    )(xp, d)
    return yp[:, :v]
