"""Algorithm 4 — Online softmax fused with TopK, plus the safe-fused baseline.

The paper's beam-search fast path: while the online normalizer sweeps the
vocabulary once, a running top-k candidate buffer ``(u, p)`` rides along
in VMEM scratch.  The scalar Algorithm 4 inserts one element at a time
into a (K+1)-slot sorted buffer; on tiled hardware we apply the same
idea at block granularity (DESIGN.md §Hardware-Adaptation):

    per block:  (m, d) ← (m, d) ⊕ (m_blk, d_blk)          [eq. 4]
                (u, p) ← top_k(concat(u, topk_blk), K)    [lines 8-15]

Both reductions are associative, so the block-merge computes exactly the
same ``(m_V, d_V, u, p)`` as the element-wise loop.  Memory traffic:
**1 load / element** (plus O(K) outputs) — versus 5 accesses / element
for safe-softmax-then-TopK run separately.

Also provided: :func:`safe_fused` — Safe softmax fused with TopK (the
middle bar of Figures 3-4): one max pass, then one fused sum+topk pass =
2 loads / element.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common, safe


def _merge_topk(u_old, p_old, vals_blk, idx_blk, k):
    """Associative top-k merge: keep the k best of (running ∪ block)."""
    from . import common

    cat_v = jnp.concatenate([u_old, vals_blk], axis=-1)
    cat_i = jnp.concatenate([p_old, idx_blk], axis=-1)
    u_new, sel = common.topk_desc(cat_v, k)
    p_new = jnp.take_along_axis(cat_i, sel, axis=-1)
    return u_new, p_new


def _online_fused_kernel(x_ref, m_ref, d_ref, u_ref, p_ref, *, k: int, block_v: int):
    """Grid: (num_v_blocks,).  Carries (m, d) and the top-k buffer (u, p)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        d_ref[...] = jnp.zeros_like(d_ref)
        u_ref[...] = jnp.full_like(u_ref, -jnp.inf)
        p_ref[...] = jnp.full_like(p_ref, -1)

    xb = common.as_f32(x_ref[...])
    b = xb.shape[0]

    # --- normalizer: one ⊕ fold per block (lines 6-7 of Algorithm 4).
    m_blk = jnp.max(xb, axis=-1)
    d_blk = jnp.sum(jnp.exp(xb - m_blk[:, None]), axis=-1)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, m_blk)
    scale_old = jnp.where(jnp.isneginf(m_old), 0.0, jnp.exp(m_old - m_new))
    d_ref[...] = d_ref[...] * scale_old + d_blk * jnp.exp(m_blk - m_new)
    m_ref[...] = m_new

    # --- running top-k: block candidates, then associative merge
    #     (lines 8-15 of Algorithm 4, blocked).
    vals_blk, idx_local = common.topk_desc(xb, k)
    idx_blk = (idx_local + j * block_v).astype(jnp.int32)
    u_new, p_new = _merge_topk(u_ref[...], p_ref[...], vals_blk, idx_blk, k)
    u_ref[...] = u_new
    p_ref[...] = p_new


def _safe_fused_kernel(x_ref, m_ref, d_ref, u_ref, p_ref, *, k: int, block_v: int):
    """Pass 2 of safe-fused: given m, carry (d, u, p) in one sweep."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.zeros_like(d_ref)
        u_ref[...] = jnp.full_like(u_ref, -jnp.inf)
        p_ref[...] = jnp.full_like(p_ref, -1)

    xb = common.as_f32(x_ref[...])
    d_ref[...] += jnp.sum(jnp.exp(xb - m_ref[...][:, None]), axis=-1)

    vals_blk, idx_local = common.topk_desc(xb, k)
    idx_blk = (idx_local + j * block_v).astype(jnp.int32)
    u_new, p_new = _merge_topk(u_ref[...], p_ref[...], vals_blk, idx_blk, k)
    u_ref[...] = u_new
    p_ref[...] = p_new


def _finalize(m, d, u, p):
    """Lines 17-19: turn raw top-k logits into probabilities."""
    vals = jnp.exp(u - m[:, None]) / d[:, None]
    return vals, p


def online_fused_raw(
    x: jax.Array, k: int, *, block_v: int | None = None
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-pass ``(m, d, u, p)`` — the shard-partial building block.

    ``u``/``p`` are the raw top-k *logits* and indices; the caller (or
    the rust coordinator, for vocabulary shards) applies eq. (4) merges
    and the final ``e^{u−m}/d`` scaling.
    """
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    common.validate_topk(v, k)
    if k > bv:
        raise ValueError(f"k={k} must not exceed block_v={bv}")
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)

    import functools

    kern = functools.partial(_online_fused_kernel, k=k, block_v=bv)
    m, d, u, p = common.kernel_call(
        kern,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((b, bv), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
    )(xp)
    return m, d, u, p


def online_fused(
    x: jax.Array, k: int, *, block_v: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Full Algorithm 4: top-k softmax probabilities in one pass.

    Returns ``(vals, idx)`` with ``vals[i] = softmax(x)[idx[i]]`` sorted
    descending.
    """
    m, d, u, p = online_fused_raw(x, k, block_v=block_v)
    return _finalize(m, d, u, p)


def safe_fused(
    x: jax.Array, k: int, *, block_v: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Safe softmax fused with TopK: 2 passes (max, then sum+topk).

    The middle bar in Figures 3-4 — fusion without the online normalizer.
    """
    b, v = x.shape
    bv = common.pick_block_v(v, block_v)
    common.validate_topk(v, k)
    if k > bv:
        raise ValueError(f"k={k} must not exceed block_v={bv}")
    m = safe.rowmax(x, block_v=bv)
    xp, nblk = common.pad_vocab(x, bv, fill=-jnp.inf)

    import functools

    kern = functools.partial(_safe_fused_kernel, k=k, block_v=bv)
    d, u, p = common.kernel_call(
        kern,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, bv), lambda j: (0, j)),
            pl.BlockSpec((b,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda j: (0,)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
    )(xp, m)
    return _finalize(m, d, u, p)
