"""Shared plumbing for the Pallas softmax kernels.

All kernels in this package operate on batched row vectors ``x : (B, V)``
and tile the vocabulary axis into blocks of ``block_v`` columns — the TPU
adaptation of the paper's CUDA "one threadblock per vector" layout (see
DESIGN.md §Hardware-Adaptation).  The helpers here handle:

* block-size selection respecting the (8, 128) TPU lane layout,
* −∞ padding of the vocabulary axis so any ``V`` works with any block
  size (``e^{−∞−m} = 0`` leaves both the max and the normalizer exact),
* the mandatory ``interpret=True`` plumbing: the CPU PJRT plugin cannot
  execute Mosaic custom-calls, so every kernel lowers through the Pallas
  interpreter (DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Lane width of the TPU vector unit; the natural V-block granularity.
LANE = 128
# Sublane count for fp32; the natural batch-block granularity.
SUBLANE = 8

# Default HBM→VMEM tile: 8 rows × 1024 logits ≈ 32 KiB of fp32, leaving
# VMEM headroom for the (m, d) carries and the top-k candidate buffers.
DEFAULT_BLOCK_V = 1024


def pick_block_v(v: int, block_v: int | None = None) -> int:
    """Choose a vocabulary block size.

    Honours an explicit request, otherwise uses ``DEFAULT_BLOCK_V``
    clamped to the (lane-rounded) vector length so tiny vectors do not
    pay for a mostly-padded block.
    """
    if block_v is not None:
        if block_v <= 0:
            raise ValueError(f"block_v must be positive, got {block_v}")
        return block_v
    rounded = ((v + LANE - 1) // LANE) * LANE
    return min(DEFAULT_BLOCK_V, max(LANE, rounded))


def pad_vocab(x: jax.Array, block_v: int, fill) -> tuple[jax.Array, int]:
    """Pad the last axis of ``x`` up to a multiple of ``block_v``.

    Returns the padded array and the number of blocks.  ``fill`` is −∞
    for max/normalizer passes (annihilates under both ``max`` and
    ``Σ e^{·}``) and 0 for plain value passes.
    """
    if x.ndim != 2:
        raise ValueError(f"expected (B, V) input, got shape {x.shape}")
    v = x.shape[-1]
    if v == 0:
        raise ValueError("softmax over an empty vector is undefined")
    pad = (-v) % block_v
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=fill)
    return x, (v + pad) // block_v


def validate_topk(v: int, k: int) -> None:
    if k <= 0:
        raise ValueError(f"k must be positive, got {k}")
    if k > v:
        raise ValueError(f"k={k} exceeds vector length V={v}")


def as_f32(x: jax.Array) -> jax.Array:
    """Kernels accumulate in fp32 regardless of the storage dtype,
    mirroring the paper's fp32 ``d`` bound analysis (§3)."""
    return x.astype(jnp.float32)


def cast_back(y: jax.Array, like: jax.Array) -> jax.Array:
    return y.astype(like.dtype)


def row_iota(shape: tuple[int, ...], axis: int) -> jax.Array:
    """Index helper usable inside Pallas kernels (≥2D iota only)."""
    return jax.lax.broadcasted_iota(jnp.int32, shape, axis)


def topk_desc(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Descending top-k via stable argsort (AOT-parser-safe).

    Used inside Pallas kernels instead of ``jax.lax.top_k`` because the
    latter lowers to an HLO ``topk`` op that the xla_extension 0.5.1
    text parser cannot ingest.  Stable ⇒ earliest index wins ties, the
    same convention as Algorithm 4's strict `<` insertion loop.
    """
    idx = jnp.argsort(-x, axis=-1, stable=True)[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def interpret_flag() -> bool:
    """Pallas must run in interpret mode on this CPU-only testbed."""
    return True


def kernel_call(kernel, **kwargs):
    """``pl.pallas_call`` with the package-wide interpret policy applied."""
    from jax.experimental import pallas as pl

    return pl.pallas_call(kernel, interpret=interpret_flag(), **kwargs)


def jit_cached(fn):
    """``jax.jit`` with static kernel-config args, cached per config."""
    return functools.partial(jax.jit, static_argnames=("block_v", "k"))(fn)
