"""Golden-vector generator: jnp reference results → JSON for rust tests.

``python -m compile.golden --out ../rust/tests/golden`` writes small,
deterministic input/output pairs computed by the :mod:`compile.kernels.ref`
oracles.  The rust test-suite (``rust/tests/golden_vectors.rs``) replays
them against the rust softmax/topk implementations, closing the loop
between the two halves of the stack without python on the rust side.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_topk, ref


def _rng(seed: int) -> jax.Array:
    return jax.random.PRNGKey(seed)


def _cases():
    """(name, x) pairs covering the interesting numeric regimes."""
    mk = jax.random.normal
    yield "gauss_small", mk(_rng(0), (3, 17)) * 2.0
    yield "gauss_wide", mk(_rng(1), (2, 257)) * 10.0
    yield "large_positive", mk(_rng(2), (2, 64)) * 5.0 + 80.0   # naive overflows
    yield "large_negative", mk(_rng(3), (2, 64)) * 5.0 - 80.0
    yield "constant_rows", jnp.full((2, 33), 3.25)
    yield "single_element", jnp.asarray([[42.0]])
    yield "two_elements", jnp.asarray([[1.0, -1.0], [5.0, 5.0]])
    yield "monotone", jnp.arange(96, dtype=jnp.float32).reshape(1, 96) / 7.0
    yield "alternating", jnp.asarray([[(-1.0) ** i * (i % 13) for i in range(101)]])


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cases = []
    for name, x in _cases():
        x = x.astype(jnp.float32)
        m, d = ref.online_normalizer(x)
        y = ref.softmax_safe(x)
        k = min(5, x.shape[-1])
        tv, tz = ref.softmax_topk(x, k)
        cases.append({
            "name": name,
            "x": np.asarray(x).tolist(),
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
            "y": np.asarray(y).tolist(),
            "k": k,
            "topk_vals": np.asarray(tv).tolist(),
            "topk_idx": np.asarray(tz).tolist(),
        })

    # ⊕-merge cases: random shard splits whose merge must equal the
    # whole-vector normalizer.
    merges = []
    for seed, (b, v, s) in enumerate([(2, 96, 3), (1, 128, 4), (4, 60, 5)]):
        x = jax.random.normal(_rng(100 + seed), (b, v)) * 4.0
        m, d = ref.online_normalizer(x)
        parts = []
        vs = v // s
        for i in range(s):
            pm, pd = ref.online_normalizer(x[:, i * vs : (i + 1) * vs])
            parts.append({"m": np.asarray(pm).tolist(), "d": np.asarray(pd).tolist()})
        merges.append({
            "parts": parts,
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
        })

    # Sharded fused softmax+topk cases: per-shard (m, d, u, p) partials
    # computed by the *Pallas* single-pass kernel
    # (compile.kernels.fused_topk.online_fused_raw, built on
    # compile.kernels.online's ⊕-carry), with the expected whole-row
    # answer from the jnp oracle.  The rust side replays the shard merge
    # (⊕ + buffer reduction) over these partials and must land on the
    # same top-k — pinning the cross-shard reduction across languages.
    shard_cases = []
    for seed, (b, v, k, s) in enumerate([(2, 96, 5, 3), (1, 200, 7, 4), (2, 64, 3, 2), (1, 128, 1, 8)]):
        assert v % s == 0, "fixture shard splits are exact"
        x = (jax.random.normal(_rng(300 + seed), (b, v)) * 6.0).astype(jnp.float32)
        m, d = ref.online_normalizer(x)
        vals, idx = ref.softmax_topk(x, k)
        vs = v // s
        parts = []
        for i in range(s):
            pm, pd, pu, pp = fused_topk.online_fused_raw(x[:, i * vs : (i + 1) * vs], k)
            parts.append({
                "m": np.asarray(pm).tolist(),
                "d": np.asarray(pd).tolist(),
                "u": np.asarray(pu).tolist(),
                # p is shard-local; the rust merge adds the shard offset
                "p": np.asarray(pp).tolist(),
            })
        shard_cases.append({
            "x": np.asarray(x).tolist(),
            "k": k,
            "shard_size": vs,
            "parts": parts,
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
            "topk_vals": np.asarray(vals).tolist(),
            "topk_idx": np.asarray(idx).tolist(),
        })

    with open(os.path.join(out_dir, "softmax_golden.json"), "w") as f:
        json.dump({"cases": cases, "merges": merges, "sharded": shard_cases}, f)
    print(
        f"wrote {len(cases)} cases + {len(merges)} merges + "
        f"{len(shard_cases)} sharded cases to {out_dir}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../rust/tests/golden")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
