"""Golden-vector generator: jnp reference results → JSON for rust tests.

``python -m compile.golden --out ../rust/tests/golden`` writes small,
deterministic input/output pairs computed by the :mod:`compile.kernels.ref`
oracles.  The rust test-suite (``rust/tests/golden_vectors.rs``) replays
them against the rust softmax/topk implementations, closing the loop
between the two halves of the stack without python on the rust side.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_topk, ref

_U64 = (1 << 64) - 1


def _rng(seed: int) -> jax.Array:
    return jax.random.PRNGKey(seed)


def _counter_hash(seed: int, counter: int) -> int:
    """SplitMix64 output finalizer over an arbitrary counter — the exact
    spec of ``sample::counter_hash`` in ``rust/src/sample/mod.rs``; the
    sampling golden vectors pin the two implementations bit for bit."""
    z = (seed + ((counter + 1) * 0x9E3779B97F4A7C15 & _U64)) & _U64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _U64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _U64
    return (z ^ (z >> 31)) & _U64


def _gumbel(seed: int, index: int) -> np.float32:
    """``sample::gumbel``: u from the hash's top 53 bits (offset ½ulp),
    g = −ln(−ln(u)) computed in f64 and rounded once to f32."""
    h = _counter_hash(seed, index & _U64)
    u = ((h >> 11) + 0.5) * (1.0 / (1 << 53))
    return np.float32(-math.log(-math.log(u)))


def _sampled_expectations(x: np.ndarray, k: int, seed: int, temperature: float):
    """Reference Gumbel-top-k selection per row: perturb in f32 exactly
    like ``sample::perturb`` (f32 divide, f32 add), rank by perturbed
    score with lower-index tie-breaking (the scan's incumbent-wins
    insertion order), drop non-finite scores (NaN / −∞ masking), and
    report the untempered probabilities ``e^{x−m}/d``."""
    t = np.float32(temperature)
    m, d = ref.online_normalizer(x)
    m, d = np.asarray(m), np.asarray(d)
    idx_rows, score_rows, val_rows = [], [], []
    for row, xr in enumerate(x):
        scored = []
        for i, v in enumerate(xr):
            s = np.float32(v) / t + _gumbel(seed, i)
            if np.isfinite(s):
                scored.append((float(s), i))
        scored.sort(key=lambda p: (-p[0], p[1]))
        top = scored[:k]
        idx_rows.append([i for _, i in top])
        score_rows.append([s for s, _ in top])
        val_rows.append([
            float(math.exp(float(xr[i]) - float(m[row])) / float(d[row])) for _, i in top
        ])
    return idx_rows, score_rows, val_rows


def _cases():
    """(name, x) pairs covering the interesting numeric regimes."""
    mk = jax.random.normal
    yield "gauss_small", mk(_rng(0), (3, 17)) * 2.0
    yield "gauss_wide", mk(_rng(1), (2, 257)) * 10.0
    yield "large_positive", mk(_rng(2), (2, 64)) * 5.0 + 80.0   # naive overflows
    yield "large_negative", mk(_rng(3), (2, 64)) * 5.0 - 80.0
    yield "constant_rows", jnp.full((2, 33), 3.25)
    yield "single_element", jnp.asarray([[42.0]])
    yield "two_elements", jnp.asarray([[1.0, -1.0], [5.0, 5.0]])
    yield "monotone", jnp.arange(96, dtype=jnp.float32).reshape(1, 96) / 7.0
    yield "alternating", jnp.asarray([[(-1.0) ** i * (i % 13) for i in range(101)]])


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cases = []
    for name, x in _cases():
        x = x.astype(jnp.float32)
        m, d = ref.online_normalizer(x)
        y = ref.softmax_safe(x)
        k = min(5, x.shape[-1])
        tv, tz = ref.softmax_topk(x, k)
        cases.append({
            "name": name,
            "x": np.asarray(x).tolist(),
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
            "y": np.asarray(y).tolist(),
            "k": k,
            "topk_vals": np.asarray(tv).tolist(),
            "topk_idx": np.asarray(tz).tolist(),
        })

    # ⊕-merge cases: random shard splits whose merge must equal the
    # whole-vector normalizer.
    merges = []
    for seed, (b, v, s) in enumerate([(2, 96, 3), (1, 128, 4), (4, 60, 5)]):
        x = jax.random.normal(_rng(100 + seed), (b, v)) * 4.0
        m, d = ref.online_normalizer(x)
        parts = []
        vs = v // s
        for i in range(s):
            pm, pd = ref.online_normalizer(x[:, i * vs : (i + 1) * vs])
            parts.append({"m": np.asarray(pm).tolist(), "d": np.asarray(pd).tolist()})
        merges.append({
            "parts": parts,
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
        })

    # Sharded fused softmax+topk cases: per-shard (m, d, u, p) partials
    # computed by the *Pallas* single-pass kernel
    # (compile.kernels.fused_topk.online_fused_raw, built on
    # compile.kernels.online's ⊕-carry), with the expected whole-row
    # answer from the jnp oracle.  The rust side replays the shard merge
    # (⊕ + buffer reduction) over these partials and must land on the
    # same top-k — pinning the cross-shard reduction across languages.
    shard_cases = []
    for seed, (b, v, k, s) in enumerate([(2, 96, 5, 3), (1, 200, 7, 4), (2, 64, 3, 2), (1, 128, 1, 8)]):
        assert v % s == 0, "fixture shard splits are exact"
        x = (jax.random.normal(_rng(300 + seed), (b, v)) * 6.0).astype(jnp.float32)
        m, d = ref.online_normalizer(x)
        vals, idx = ref.softmax_topk(x, k)
        vs = v // s
        parts = []
        for i in range(s):
            pm, pd, pu, pp = fused_topk.online_fused_raw(x[:, i * vs : (i + 1) * vs], k)
            parts.append({
                "m": np.asarray(pm).tolist(),
                "d": np.asarray(pd).tolist(),
                "u": np.asarray(pu).tolist(),
                # p is shard-local; the rust merge adds the shard offset
                "p": np.asarray(pp).tolist(),
            })
        shard_cases.append({
            "x": np.asarray(x).tolist(),
            "k": k,
            "shard_size": vs,
            "parts": parts,
            "m": np.asarray(m).tolist(),
            "d": np.asarray(d).tolist(),
            "topk_vals": np.asarray(vals).tolist(),
            "topk_idx": np.asarray(idx).tolist(),
        })

    # Seeded Gumbel-top-k sampling cases: the counter-based draw spec is
    # implemented twice (here and in rust/src/sample/mod.rs); these pin
    # the raw draws bit for bit and the fused sampled selection
    # (indices + f32 perturbed scores exact, untempered probabilities to
    # tolerance — the rust side finalizes through its fast_exp).
    gumbel_pins = [
        {"seed": s, "index": i, "g": float(_gumbel(s, i))}
        for s, i in [
            (0, 0),
            (42, 0),
            (42, 1),
            (42, 1023),
            (123, 7),
            (0xDEADBEEF, 65535),
        ]
    ]
    sampled_cases = []
    for name, shape, k, seed, temperature, scale, shift in [
        ("gauss_cold", (2, 64), 5, 17, 0.7, 4.0, 0.0),
        ("wide_unit", (1, 128), 3, 99, 1.0, 8.0, 0.0),
        ("hot", (2, 48), 4, 5, 1.5, 3.0, 2.0),
        ("k_beyond_v", (1, 6), 8, 7, 0.9, 2.0, 0.0),
    ]:
        x = (jax.random.normal(_rng(500 + seed), shape) * scale + shift).astype(jnp.float32)
        xn = np.asarray(x)
        idx_rows, score_rows, val_rows = _sampled_expectations(xn, k, seed, temperature)
        sampled_cases.append({
            "name": name,
            "x": xn.tolist(),
            "k": k,
            "seed": seed,
            "temperature": temperature,
            "idx": idx_rows,
            "scores": score_rows,
            "vals": val_rows,
        })
    # Constant row: every logit ties, so the selection is decided purely
    # by the perturbation stream — the strongest pin on the draw order.
    xc = np.full((2, 33), 3.25, dtype=np.float32)
    idx_rows, score_rows, val_rows = _sampled_expectations(xc, 4, 11, 1.0)
    sampled_cases.append({
        "name": "constant_rows",
        "x": xc.tolist(),
        "k": 4,
        "seed": 11,
        "temperature": 1.0,
        "idx": idx_rows,
        "scores": score_rows,
        "vals": val_rows,
    })

    with open(os.path.join(out_dir, "softmax_golden.json"), "w") as f:
        json.dump({
            "cases": cases,
            "merges": merges,
            "sharded": shard_cases,
            "sampling": {"gumbel": gumbel_pins, "cases": sampled_cases},
        }, f)
    print(
        f"wrote {len(cases)} cases + {len(merges)} merges + "
        f"{len(shard_cases)} sharded cases + {len(sampled_cases)} sampled cases to {out_dir}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../rust/tests/golden")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
