"""Pytest wiring: make the ``compile`` package importable regardless of
where pytest is invoked from, and pin hypothesis to interpreter-friendly
profiles (Pallas interpret mode is slow per example)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import HealthCheck, settings

settings.register_profile(
    "kernels",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")
