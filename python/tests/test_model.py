"""L2 correctness: decode graphs, shard-partial contract, toy LM step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _hw(seed, b=4, h=32, v=512):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    h_ = jax.random.normal(k1, (b, h), jnp.float32)
    w_ = jax.random.normal(k2, (v, h), jnp.float32) * 0.3
    return h_, w_


class TestDecodeVariants:
    def test_safe_and_online_agree(self):
        h, w = _hw(0)
        v1, z1 = model.decode_topk_jnp(h, w, k=5)
        v2, z2 = model.decode_topk_online_jnp(h, w, k=5)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))

    def test_pallas_decode_agrees(self):
        h, w = _hw(1, b=2, h=16, v=256)
        v1, z1 = model.decode_topk_jnp(h, w, k=5)
        v2, z2 = model.decode_topk_pallas(h, w, k=5)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))

    def test_topk_values_are_probabilities_of_logits(self):
        h, w = _hw(2)
        logits = model.project(h, w)
        v, z = model.decode_topk_jnp(h, w, k=7)
        y = np.asarray(ref.softmax_safe(logits))
        for b in range(h.shape[0]):
            np.testing.assert_allclose(np.asarray(v)[b], y[b][np.asarray(z)[b]], rtol=1e-5)


class TestShardedDecode:
    """The L3 merge contract: shard partials ⊕-merge to the full answer."""

    @pytest.mark.parametrize("shards", [2, 4, 8])
    def test_merged_shards_equal_full(self, shards):
        b, hdim, v, k = 3, 32, 512, 5
        h, w = _hw(3, b=b, h=hdim, v=v)
        vs = v // shards

        # full-vocab reference
        rv, rz = model.decode_topk_jnp(h, w, k=k)

        # shard partials + python rendition of the rust merge
        m_acc, d_acc = ref.md_identity((b,))
        u_all, p_all = [], []
        for s in range(shards):
            w_s = w[s * vs : (s + 1) * vs]
            m, d, u, p = model.decode_partial_jnp(h, w_s, k=k)
            m_acc, d_acc = ref.md_combine((m_acc, d_acc), (m, d))
            u_all.append(np.asarray(u))
            p_all.append(np.asarray(p) + s * vs)  # globalize indices

        u_cat = np.concatenate(u_all, -1)
        p_cat = np.concatenate(p_all, -1)
        order = np.argsort(-u_cat, axis=-1, kind="stable")[:, :k]
        u_top = np.take_along_axis(u_cat, order, -1)
        p_top = np.take_along_axis(p_cat, order, -1)
        vals = np.exp(u_top - np.asarray(m_acc)[:, None]) / np.asarray(d_acc)[:, None]

        np.testing.assert_allclose(vals, np.asarray(rv), rtol=1e-5)
        np.testing.assert_array_equal(p_top, np.asarray(rz))

    def test_pallas_partial_matches_jnp_partial(self):
        h, w = _hw(4, b=2, h=16, v=256)
        out_j = model.decode_partial_jnp(h, w, k=5)
        out_p = model.decode_partial_pallas(h, w, k=5)
        for a, b_ in zip(out_j, out_p):
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float64), np.asarray(b_, dtype=np.float64), rtol=1e-5
            )

    def test_sharded_softmax_two_pass(self):
        """softmax_partial + coordinator merge + softmax_scale == safe softmax."""
        b, v, shards = 2, 384, 3
        x = jax.random.normal(jax.random.PRNGKey(5), (b, v), jnp.float32) * 6
        vs = v // shards
        m_acc, d_acc = ref.md_identity((b,))
        for s in range(shards):
            part = model.softmax_partial_jnp(x[:, s * vs : (s + 1) * vs])
            m_acc, d_acc = ref.md_combine((m_acc, d_acc), part)
        pieces = [
            np.asarray(model.softmax_scale_jnp(x[:, s * vs : (s + 1) * vs], m_acc, d_acc)[0])
            for s in range(shards)
        ]
        y = np.concatenate(pieces, -1)
        np.testing.assert_allclose(y, np.asarray(ref.softmax_safe(x)), rtol=1e-5)


class TestToyLm:
    def test_step_shapes_and_determinism(self):
        v, hdim, b = 64, 16, 3
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        emb = jax.random.normal(ks[0], (v, hdim))
        w1 = jax.random.normal(ks[1], (hdim, hdim)) * 0.2
        w2 = jax.random.normal(ks[2], (hdim, hdim)) * 0.2
        state = jnp.zeros((b, hdim))
        tok = jnp.asarray([1, 5, 9], jnp.int32)
        (s1,) = model.toy_lm_step(emb, w1, w2, state, tok)
        (s2,) = model.toy_lm_step(emb, w1, w2, state, tok)
        assert s1.shape == (b, hdim)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        assert np.all(np.abs(np.asarray(s1)) <= 1.0)

    def test_step_depends_on_token(self):
        v, hdim = 32, 8
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 3)
        emb = jax.random.normal(ks[0], (v, hdim))
        w1 = jnp.eye(hdim) * 0.5
        w2 = jnp.eye(hdim) * 0.5
        state = jax.random.normal(ks[1], (1, hdim))
        (a,) = model.toy_lm_step(emb, w1, w2, state, jnp.asarray([0], jnp.int32))
        (b_,) = model.toy_lm_step(emb, w1, w2, state, jnp.asarray([7], jnp.int32))
        assert not np.allclose(np.asarray(a), np.asarray(b_))
