"""L1 correctness: Pallas softmax kernels vs the jnp oracle.

Hypothesis sweeps shapes, block sizes, magnitudes, and dtypes; each
kernel must agree with :func:`ref.softmax_safe` (naive only within its
non-overflowing range) and the online normalizer must match the
whole-vector ``(m, d)`` bit-for-bit up to fp reassociation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, naive, online, ref, safe

shapes = st.tuples(st.integers(1, 6), st.integers(1, 700))
blocks = st.sampled_from([16, 128, 256, 1024])
scales = st.sampled_from([0.1, 1.0, 8.0, 30.0])


def _rand(seed, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


@given(st.integers(0, 2**31 - 1), shapes, blocks, scales)
def test_online_softmax_matches_ref(seed, shape, block_v, scale):
    x = _rand(seed, shape, scale)
    y = online.softmax(x, block_v=block_v)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.softmax_safe(x)), rtol=2e-5, atol=1e-7
    )


@given(st.integers(0, 2**31 - 1), shapes, blocks, scales)
def test_safe_softmax_matches_ref(seed, shape, block_v, scale):
    x = _rand(seed, shape, scale)
    y = safe.softmax(x, block_v=block_v)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.softmax_safe(x)), rtol=2e-5, atol=1e-7
    )


@given(st.integers(0, 2**31 - 1), shapes, blocks)
def test_naive_softmax_matches_ref_in_safe_range(seed, shape, block_v):
    # moderate magnitudes only: naive is *expected* to overflow beyond ~88
    x = _rand(seed, shape, 3.0)
    y = naive.softmax(x, block_v=block_v)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.softmax_safe(x)), rtol=2e-5, atol=1e-7
    )


@given(st.integers(0, 2**31 - 1), shapes, blocks, scales)
def test_online_normalizer_matches_ref(seed, shape, block_v, scale):
    x = _rand(seed, shape, scale)
    m, d = online.normalizer(x, block_v=block_v)
    rm, rd = ref.online_normalizer(x)
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm))  # max is exact
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=2e-6)


@given(st.integers(0, 2**31 - 1), shapes, blocks)
def test_safe_normalizer_matches_online(seed, shape, block_v):
    """Algorithms 2 and 3 compute the same (m, d) — Theorem 1."""
    x = _rand(seed, shape, 10.0)
    m2, d2 = safe.normalizer(x, block_v=block_v)
    m3, d3 = online.normalizer(x, block_v=block_v)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m3))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d3), rtol=2e-6)


class TestNumericalSafety:
    def test_online_immune_to_large_inputs(self):
        x = jnp.full((2, 300), 200.0)
        y = np.asarray(online.softmax(x, block_v=128))
        assert np.all(np.isfinite(y))
        np.testing.assert_allclose(y.sum(-1), np.ones(2), rtol=1e-5)

    def test_naive_overflows_where_online_does_not(self):
        x = jnp.full((1, 64), 120.0)
        assert not np.all(np.isfinite(np.asarray(naive.softmax(x, block_v=64))))
        assert np.all(np.isfinite(np.asarray(online.softmax(x, block_v=64))))

    def test_d_bound_holds_blockwise(self):
        """1 ≤ d ≤ V survives the tiled ⊕ evaluation order."""
        for v, bv in [(100, 16), (1000, 128), (515, 256)]:
            x = _rand(v, (2, v), 25.0)
            _, d = online.normalizer(x, block_v=bv)
            d = np.asarray(d)
            assert np.all(d >= 1.0 - 1e-5) and np.all(d <= v * (1 + 1e-5))


class TestBlockEdgeCases:
    @pytest.mark.parametrize("v", [1, 2, 15, 16, 17, 127, 128, 129, 1023, 1024, 1025])
    def test_all_divisibility_regimes(self, v):
        x = _rand(v, (3, v), 4.0)
        np.testing.assert_allclose(
            np.asarray(online.softmax(x, block_v=128)),
            np.asarray(ref.softmax_safe(x)),
            rtol=2e-5, atol=1e-7,
        )

    def test_block_larger_than_vector(self):
        x = _rand(0, (2, 10), 2.0)
        np.testing.assert_allclose(
            np.asarray(online.softmax(x, block_v=1024)),
            np.asarray(ref.softmax_safe(x)),
            rtol=2e-5,
        )

    def test_default_block_pick(self):
        assert common.pick_block_v(50) == 128
        assert common.pick_block_v(3000) == 1024
        assert common.pick_block_v(3000, 256) == 256

    def test_rejects_empty_vector(self):
        with pytest.raises(ValueError):
            online.softmax(jnp.zeros((2, 0)), block_v=16)

    def test_rejects_bad_block(self):
        with pytest.raises(ValueError):
            common.pick_block_v(10, 0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_roundtrip(dtype):
    x = (_rand(7, (2, 200), 3.0)).astype(dtype)
    y = online.softmax(x, block_v=128)
    assert y.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(y, dtype=np.float32),
        np.asarray(ref.softmax_safe(x), dtype=np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
        atol=1e-3 if dtype == jnp.bfloat16 else 1e-7,
    )
