"""AOT pipeline: lowering produces loadable HLO text + a sound manifest."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_hlo_text_has_entry(self):
        text = aot.lower_entry(model.softmax_safe_jnp, (aot._f32(2, 64),))
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_hlo_text_no_serialized_proto(self):
        """Interchange must be text — binary protos break xla_extension 0.5.1."""
        text = aot.lower_entry(model.softmax_safe_jnp, (aot._f32(2, 64),))
        assert text.isprintable() or "\n" in text

    def test_lower_pallas_entry(self):
        text = aot.lower_entry(model.softmax_online_pallas, (aot._f32(2, 128),))
        assert text.startswith("HloModule")


class TestCatalogue:
    def test_default_catalogue_complete(self):
        cat = aot.build_catalogue()
        names = [c[0] for c in cat]
        assert len(names) == len(set(names)), "duplicate artifact names"
        for b in aot.DEFAULT_BATCH_BUCKETS:
            assert f"softmax_safe_b{b}_v{aot.DEFAULT_VOCAB}" in names
            assert any(n.startswith(f"decode_topk_b{b}_") for n in names)
            assert any(n.startswith(f"decode_partial_b{b}_") for n in names)
        assert any("pallas" in n for n in names)

    def test_shard_divisibility_enforced(self):
        with pytest.raises(ValueError):
            aot.build_catalogue(vocab=100, shards=3)

    def test_catalogue_meta_consistent(self):
        for name, _, args, meta in aot.build_catalogue(with_pallas=False):
            assert meta["batch"] in aot.DEFAULT_BATCH_BUCKETS
            if meta["variant"].startswith("decode"):
                # h input is (B, H); w input is (V|Vs, H)
                assert args[0].shape == (meta["batch"], meta["hidden"])
                assert args[1].shape[1] == meta["hidden"]


class TestWriteArtifacts(object):
    def test_manifest_roundtrip(self, tmp_path):
        cat = [(
            "softmax_safe_b2_v64",
            model.softmax_safe_jnp,
            (aot._f32(2, 64),),
            dict(variant="softmax_safe", batch=2, vocab=64),
        )]
        manifest = aot.write_artifacts(str(tmp_path), cat, verbose=False)
        with open(tmp_path / "manifest.json") as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        (entry,) = on_disk["artifacts"]
        assert entry["inputs"] == [{"shape": [2, 64], "dtype": "float32"}]
        assert entry["outputs"] == [{"shape": [2, 64], "dtype": "float32"}]
        hlo = (tmp_path / entry["file"]).read_text()
        assert hlo.startswith("HloModule")
        import hashlib
        assert entry["sha256"] == hashlib.sha256(hlo.encode()).hexdigest()

    def test_partial_outputs_shapes(self, tmp_path):
        import functools
        cat = [(
            "decode_partial_b2_h8_vs32_k3",
            functools.partial(model.decode_partial_jnp, k=3),
            (aot._f32(2, 8), aot._f32(32, 8)),
            dict(variant="decode_partial", batch=2, vocab=32, hidden=8, k=3,
                 shard_count=4, full_vocab=128),
        )]
        manifest = aot.write_artifacts(str(tmp_path), cat, verbose=False)
        outs = manifest["artifacts"][0]["outputs"]
        assert [o["shape"] for o in outs] == [[2], [2], [2, 3], [2, 3]]
        assert outs[3]["dtype"] == "int32"
