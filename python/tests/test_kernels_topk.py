"""L1 correctness: fused online-softmax+topk (Algorithm 4) vs the oracle.

Tie-handling note: when duplicate logits straddle a block boundary the
*index* choice between equal values is implementation-defined (the paper's
Algorithm 4 keeps the earliest; ``lax.top_k`` on the concatenated buffer
keeps the first occurrence in buffer order).  Tests therefore assert the
strong property that is well-defined — returned (value, index) pairs are
self-consistent and the value multiset equals the true top-k — and check
exact index equality only on tie-free inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import fused_topk, ref

shapes_k = st.tuples(st.integers(1, 5), st.integers(8, 600)).flatmap(
    lambda bv: st.tuples(st.just(bv[0]), st.just(bv[1]), st.integers(1, min(8, bv[1])))
)
blocks = st.sampled_from([64, 128, 256])


def _rand(seed, shape, scale=4.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


def _check_against_ref(fn, x, k, block_v):
    v, z = fn(x, k, block_v=block_v)
    rv, rz = ref.softmax_topk(x, k)
    v, z, rv, rz = map(np.asarray, (v, z, rv, rz))
    y = np.asarray(ref.softmax_safe(x))
    b = x.shape[0]
    np.testing.assert_allclose(v, rv, rtol=2e-5, atol=1e-8)
    for i in range(b):
        assert np.all(z[i] >= 0) and np.all(z[i] < x.shape[1])
        # each reported index really carries its reported probability
        np.testing.assert_allclose(y[i][z[i]], v[i], rtol=2e-5, atol=1e-8)
        assert np.all(np.diff(v[i]) <= 1e-7), "descending order"


@given(st.integers(0, 2**31 - 1), shapes_k, blocks)
def test_online_fused_matches_ref(seed, bvk, block_v):
    b, v, k = bvk
    _check_against_ref(fused_topk.online_fused, _rand(seed, (b, v)), k, block_v)


@given(st.integers(0, 2**31 - 1), shapes_k, blocks)
def test_safe_fused_matches_ref(seed, bvk, block_v):
    b, v, k = bvk
    _check_against_ref(fused_topk.safe_fused, _rand(seed, (b, v)), k, block_v)


@given(st.integers(0, 2**31 - 1), shapes_k, blocks)
def test_raw_partials_finalize_correctly(seed, bvk, block_v):
    """online_fused_raw (m, d, u, p) is the shard-partial contract."""
    b, v, k = bvk
    x = _rand(seed, (b, v))
    m, d, u, p = fused_topk.online_fused_raw(x, k, block_v=block_v)
    rm, rd = ref.online_normalizer(x)
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm))
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=2e-6)
    # u holds raw logits of the top-k entries
    ru, _ = ref.topk(x, k)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ru), rtol=1e-6)


def test_exact_indices_tie_free():
    """On tie-free input, fused indices equal the oracle exactly."""
    x = jnp.asarray(np.random.default_rng(0).permutation(900).reshape(3, 300).astype(np.float32))
    v, z = fused_topk.online_fused(x, 5, block_v=64)
    rv, rz = ref.softmax_topk(x, 5)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(rz))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)


def test_k_equals_block():
    x = _rand(0, (2, 256))
    _check_against_ref(fused_topk.online_fused, x, 64, 64)


def test_k_equals_v():
    x = _rand(1, (2, 32))
    _check_against_ref(fused_topk.online_fused, x, 32, 32)


def test_k1_is_argmax():
    x = _rand(2, (4, 333))
    v, z = fused_topk.online_fused(x, 1, block_v=128)
    assert np.array_equal(np.asarray(z)[:, 0], np.argmax(np.asarray(x), -1))


def test_paper_k_sweep_values():
    """K values the paper benchmarks (§5.2) all remain correct."""
    x = _rand(3, (2, 2048))
    for k in (5, 10, 15, 30):
        _check_against_ref(fused_topk.online_fused, x, k, 256)


def test_probabilities_bounded():
    v, _ = fused_topk.online_fused(_rand(4, (3, 500), 30.0), 5, block_v=128)
    v = np.asarray(v)
    assert np.all(v > 0) and np.all(v <= 1.0 + 1e-6)


class TestValidation:
    def test_rejects_k_gt_v(self):
        with pytest.raises(ValueError):
            fused_topk.online_fused(jnp.zeros((1, 4)), 5, block_v=4)

    def test_rejects_k_gt_block(self):
        with pytest.raises(ValueError):
            fused_topk.online_fused(jnp.zeros((1, 100)), 50, block_v=32)

    def test_rejects_nonpositive_k(self):
        with pytest.raises(ValueError):
            fused_topk.online_fused(jnp.zeros((1, 10)), 0, block_v=16)
