"""Oracle sanity: the pure-jnp references must themselves be right.

Everything downstream (Pallas kernels, rust golden vectors, the serving
artifacts) is validated against :mod:`compile.kernels.ref`, so this file
pins the oracles to first principles: agreement with ``jax.nn.softmax``,
probability-simplex invariants, the paper's boundedness claims for the
normalizer, and the monoid laws of the ⊕ operator (§3.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import ref


def _rand(seed, shape, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


class TestSafeSoftmax:
    def test_matches_jax_nn(self):
        x = _rand(0, (5, 131))
        np.testing.assert_allclose(
            np.asarray(ref.softmax_safe(x)), np.asarray(jax.nn.softmax(x, axis=-1)),
            rtol=1e-6,
        )

    def test_rows_sum_to_one(self):
        y = np.asarray(ref.softmax_safe(_rand(1, (7, 64), scale=20.0)))
        np.testing.assert_allclose(y.sum(-1), np.ones(7), rtol=1e-5)

    def test_shift_invariance(self):
        x = _rand(2, (3, 50))
        np.testing.assert_allclose(
            np.asarray(ref.softmax_safe(x)),
            np.asarray(ref.softmax_safe(x + 123.0)),
            rtol=1e-5,
        )

    def test_no_overflow_at_extremes(self):
        x = jnp.asarray([[1000.0, 999.0, -1000.0]])
        y = np.asarray(ref.softmax_safe(x))
        assert np.all(np.isfinite(y))
        np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-6)

    def test_single_element_is_one(self):
        np.testing.assert_allclose(np.asarray(ref.softmax_safe(jnp.asarray([[7.0]]))), [[1.0]])


class TestNaiveSoftmax:
    def test_agrees_with_safe_in_moderate_range(self):
        x = _rand(3, (4, 80))
        np.testing.assert_allclose(
            np.asarray(ref.softmax_naive(x)), np.asarray(ref.softmax_safe(x)), rtol=1e-5
        )

    def test_overflows_for_large_inputs(self):
        """The very failure mode motivating Algorithm 2 (§2)."""
        x = jnp.asarray([[100.0, 100.0]])
        y = np.asarray(ref.softmax_naive(x))
        assert not np.all(np.isfinite(y)) or np.all(np.isnan(y))


class TestOnlineNormalizer:
    def test_matches_direct_formula(self):
        x = _rand(4, (6, 97))
        m, d = ref.online_normalizer(x)
        xm = np.asarray(x)
        np.testing.assert_allclose(np.asarray(m), xm.max(-1))
        np.testing.assert_allclose(
            np.asarray(d), np.exp(xm - xm.max(-1, keepdims=True)).sum(-1), rtol=1e-6
        )

    def test_paper_bound_1_le_d_le_v(self):
        """§3: 1 ≤ d_j ≤ j for all j — here at j = V."""
        for seed in range(5):
            v = 37 + seed * 50
            _, d = ref.online_normalizer(_rand(seed, (3, v), scale=30.0))
            d = np.asarray(d)
            assert np.all(d >= 1.0 - 1e-6), d.min()
            assert np.all(d <= v + 1e-3), d.max()


class TestMdMonoid:
    """⊕ (eq. 4) must be a commutative monoid with identity (−∞, 0)."""

    @staticmethod
    def _md(seed, scale=5.0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        m = jax.random.normal(k1, ()) * scale
        d = jax.random.uniform(k2, (), minval=0.1, maxval=10.0)
        return m, d

    @given(st.integers(0, 10_000), st.integers(0, 10_000))
    def test_commutative(self, s1, s2):
        a, b = self._md(s1), self._md(s2)
        ab = ref.md_combine(a, b)
        ba = ref.md_combine(b, a)
        np.testing.assert_allclose(np.asarray(ab[0]), np.asarray(ba[0]))
        np.testing.assert_allclose(np.asarray(ab[1]), np.asarray(ba[1]), rtol=1e-6)

    @given(st.integers(0, 10_000), st.integers(0, 10_000), st.integers(0, 10_000))
    def test_associative(self, s1, s2, s3):
        a, b, c = self._md(s1), self._md(s2), self._md(s3)
        left = ref.md_combine(ref.md_combine(a, b), c)
        right = ref.md_combine(a, ref.md_combine(b, c))
        np.testing.assert_allclose(np.asarray(left[0]), np.asarray(right[0]))
        np.testing.assert_allclose(np.asarray(left[1]), np.asarray(right[1]), rtol=1e-5)

    @given(st.integers(0, 10_000))
    def test_identity(self, s):
        a = self._md(s)
        e = ref.md_identity()
        for combined in (ref.md_combine(a, e), ref.md_combine(e, a)):
            np.testing.assert_allclose(np.asarray(combined[0]), np.asarray(a[0]))
            np.testing.assert_allclose(np.asarray(combined[1]), np.asarray(a[1]), rtol=1e-6)

    def test_shard_merge_equals_whole(self):
        """Splitting a vector and ⊕-merging equals the whole-vector (m, d)."""
        x = _rand(9, (4, 120), scale=8.0)
        m_ref, d_ref = ref.online_normalizer(x)
        acc = ref.md_identity((4,))
        for i in range(6):
            part = ref.online_normalizer(x[:, i * 20 : (i + 1) * 20])
            acc = ref.md_combine(acc, part)
        np.testing.assert_allclose(np.asarray(acc[0]), np.asarray(m_ref))
        np.testing.assert_allclose(np.asarray(acc[1]), np.asarray(d_ref), rtol=1e-5)


class TestTopK:
    def test_values_and_indices_consistent(self):
        x = _rand(5, (3, 67))
        v, z = ref.softmax_topk(x, 5)
        y = np.asarray(ref.softmax_safe(x))
        v, z = np.asarray(v), np.asarray(z)
        for b in range(3):
            np.testing.assert_allclose(v[b], y[b][z[b]], rtol=1e-6)
            # sorted descending, and truly the largest
            assert np.all(np.diff(v[b]) <= 1e-7)
            np.testing.assert_allclose(v[b], np.sort(y[b])[::-1][:5], rtol=1e-6)

    def test_k_equals_v(self):
        x = _rand(6, (2, 8))
        v, z = ref.softmax_topk(x, 8)
        assert v.shape == (2, 8) and z.shape == (2, 8)
        np.testing.assert_allclose(np.asarray(v).sum(-1), np.ones(2), rtol=1e-5)
