//! Repository lint gate: `cargo run -p xtask -- lint`.
//!
//! Four std-only static checks over `rust/src` (the offline registry
//! ships no lint crates, so the gate is a first-class workspace
//! binary; CI treats a nonzero exit as a hard failure):
//!
//! 1. **unsafe-safety** — every `unsafe` keyword must carry a
//!    `// SAFETY:` justification on the same line or in the comment
//!    block immediately above its statement.  Mirrors clippy's
//!    `undocumented_unsafe_blocks`, but also covers `unsafe impl` /
//!    `unsafe fn` and runs without network access.
//! 2. **atomics-allowlist** — every atomic `Ordering::X` use must be
//!    named in its file's `// xtask:atomics-allowlist:` header, so a
//!    new ordering (or a relaxation) can only land together with a
//!    written-down audit of why it is sound.
//! 3. **no-panic** — `.unwrap()` / `.expect(` are banned in non-test
//!    server and coordinator code: serving paths must return typed
//!    errors, not abort a worker.  Poison-propagating lock/condvar
//!    unwraps are idiomatic and allowed; anything else needs an
//!    explicit `// panic-ok: <why>` waiver on the line or in the
//!    comment block above.
//! 4. **config-drift** — the `--flag` tables in `docs/CONFIG.md` and
//!    the body of `cli::help_text` must agree in *both* directions: a
//!    knob documented but not offered, or offered but not documented,
//!    fails the gate.
//!
//! `cargo test -p xtask` seeds one violation of each class into
//! fixture trees and asserts the linter catches it, then asserts the
//! real tree is clean.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::from(2)
        }
    }
}

fn run_lint() -> ExitCode {
    let violations = lint_tree(&repo_root());
    if violations.is_empty() {
        println!("xtask lint: clean");
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    println!("xtask lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}

/// Workspace root: xtask lives one level below it.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits inside the workspace")
        .to_path_buf()
}

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

impl Violation {
    fn new(file: &str, line: usize, rule: &'static str, message: String) -> Violation {
        Violation { file: file.to_string(), line, rule, message }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Run every check against the tree rooted at `root`.
fn lint_tree(root: &Path) -> Vec<Violation> {
    let mut files = Vec::new();
    rs_files(&root.join("rust/src"), &mut files);
    let mut out = Vec::new();
    for path in files {
        let Ok(text) = fs::read_to_string(&path) else { continue };
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let lines: Vec<&str> = text.lines().collect();
        check_unsafe(&rel, &lines, &mut out);
        check_atomics(&rel, &lines, &mut out);
        if rel.starts_with("rust/src/server") || rel.starts_with("rust/src/coordinator") {
            check_panics(&rel, &lines, &mut out);
        }
    }
    check_config_drift(root, &mut out);
    out
}

/// All `.rs` files under `dir`, in a stable order.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// The source text of `line` with any `//` comment cut off.
fn code_of(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whether `code` contains `word` as a standalone token (so
/// `undocumented_unsafe_blocks` does not count as `unsafe`).
fn has_keyword(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let pre = start == 0 || !is_word_byte(bytes[start - 1]);
        let post = end == bytes.len() || !is_word_byte(bytes[end]);
        if pre && post {
            return true;
        }
        from = end;
    }
    false
}

// ---------------------------------------------------------------- rule 1

fn check_unsafe(path: &str, lines: &[&str], out: &mut Vec<Violation>) {
    for (i, line) in lines.iter().enumerate() {
        if !has_keyword(code_of(line), "unsafe") {
            continue;
        }
        if !safety_documented(lines, i) {
            out.push(Violation::new(
                path,
                i + 1,
                "unsafe-safety",
                "`unsafe` without a `// SAFETY:` comment on the line or in the \
                 comment block above its statement"
                    .to_string(),
            ));
        }
    }
}

/// `SAFETY` on the line itself, or in a comment found by walking up to
/// 10 nonblank lines: comment and attribute lines are stepped over, and
/// a code line that *ends* a previous statement (`;`, `{` or `}`) stops
/// the walk, so a multi-line `let … = unsafe { … }` still sees the
/// comment above its `let`.
fn safety_documented(lines: &[&str], idx: usize) -> bool {
    if lines[idx].contains("SAFETY") {
        return true;
    }
    let mut seen = 0;
    let mut i = idx;
    while i > 0 && seen < 10 {
        i -= 1;
        let t = lines[i].trim();
        if t.is_empty() {
            continue;
        }
        seen += 1;
        if t.starts_with("//") {
            if t.contains("SAFETY") {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#!") {
            // Attributes sit between a comment and its item.
        } else if t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------- rule 2

const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
const ALLOWLIST_MARKER: &str = "xtask:atomics-allowlist:";

fn check_atomics(path: &str, lines: &[&str], out: &mut Vec<Violation>) {
    let mut allow: Option<Vec<String>> = None;
    for line in lines {
        if let Some(pos) = line.find(ALLOWLIST_MARKER) {
            let list = &line[pos + ALLOWLIST_MARKER.len()..];
            allow = Some(list.split(',').map(|s| s.trim().to_string()).collect());
        }
    }
    for (i, line) in lines.iter().enumerate() {
        let code = code_of(line);
        let mut from = 0;
        while let Some(pos) = code[from..].find("Ordering::") {
            let start = from + pos + "Ordering::".len();
            let ident: String = code[start..]
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            from = start + ident.len();
            if !ATOMIC_ORDERINGS.contains(&ident.as_str()) {
                continue; // std::cmp::Ordering and friends
            }
            match &allow {
                None => {
                    out.push(Violation::new(
                        path,
                        i + 1,
                        "atomics-allowlist",
                        format!(
                            "Ordering::{ident} used but the file has no \
                             `// {ALLOWLIST_MARKER}` header"
                        ),
                    ));
                    return; // one missing-header complaint per file
                }
                Some(list) if !list.iter().any(|a| a == &ident) => {
                    out.push(Violation::new(
                        path,
                        i + 1,
                        "atomics-allowlist",
                        format!(
                            "Ordering::{ident} is not in this file's \
                             `// {ALLOWLIST_MARKER}` header"
                        ),
                    ));
                }
                Some(_) => {}
            }
        }
    }
}

// ---------------------------------------------------------------- rule 3

fn check_panics(path: &str, lines: &[&str], out: &mut Vec<Violation>) {
    for (i, line) in lines.iter().enumerate() {
        if line.trim() == "#[cfg(test)]" {
            break; // tests may panic freely
        }
        let code = code_of(line);
        // Poison-propagating unwraps are idiomatic: a poisoned mutex or
        // condvar means a worker already panicked, and unwrapping
        // propagates that panic rather than minting a new failure mode.
        let stripped = code.replace(".lock().unwrap()", "");
        let wait_poison = stripped.contains(".wait(") || stripped.contains(".wait_timeout(");
        let bad_unwrap = stripped.contains(".unwrap()") && !wait_poison;
        let bad_expect = stripped.contains(".expect(");
        if !(bad_unwrap || bad_expect) || panic_waived(lines, i) {
            continue;
        }
        out.push(Violation::new(
            path,
            i + 1,
            "no-panic",
            "`.unwrap()`/`.expect()` in serving code — return a typed error, or \
             waive with `// panic-ok: <why>`"
                .to_string(),
        ));
    }
}

/// `panic-ok:` on the line itself, or anywhere in the contiguous
/// comment block directly above it.
fn panic_waived(lines: &[&str], idx: usize) -> bool {
    if lines[idx].contains("panic-ok:") {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = lines[i].trim();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains("panic-ok:") {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------- rule 4

const CONFIG_REL: &str = "docs/CONFIG.md";
const HELP_REL: &str = "rust/src/cli/mod.rs";

fn check_config_drift(root: &Path, out: &mut Vec<Violation>) {
    let Ok(config) = fs::read_to_string(root.join(CONFIG_REL)) else { return };
    let Ok(help) = fs::read_to_string(root.join(HELP_REL)) else { return };

    // CONFIG.md side: knob-table rows, which all start `| `--name …` |`.
    let mut documented: Vec<(String, usize)> = Vec::new();
    for (i, line) in config.lines().enumerate() {
        let Some(rest) = line.trim_start().strip_prefix("| `--") else { continue };
        let name: String =
            rest.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '-').collect();
        if !name.is_empty() {
            documented.push((name, i + 1));
        }
    }

    // help_text side: every `--flag` token inside the function body.
    let lines: Vec<&str> = help.lines().collect();
    let Some(start) = lines.iter().position(|l| l.contains("pub fn help_text")) else { return };
    let end = lines[start..].iter().position(|l| *l == "}").map_or(lines.len(), |p| start + p);
    let mut offered: Vec<(String, usize)> = Vec::new();
    for (i, line) in lines[start..end].iter().enumerate() {
        let bytes = line.as_bytes();
        let mut from = 0;
        while let Some(pos) = line[from..].find("--") {
            let s = from + pos;
            from = s + 2;
            if s > 0 && (bytes[s - 1] == b'-' || is_word_byte(bytes[s - 1])) {
                continue; // `---` runs or mid-word dashes
            }
            let name: String = line[s + 2..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                .collect();
            if !name.is_empty() {
                offered.push((name, start + i + 1));
            }
        }
    }

    let doc_set: BTreeSet<&str> = documented.iter().map(|(n, _)| n.as_str()).collect();
    let offer_set: BTreeSet<&str> = offered.iter().map(|(n, _)| n.as_str()).collect();
    for (name, ln) in &documented {
        if !offer_set.contains(name.as_str()) {
            out.push(Violation::new(
                CONFIG_REL,
                *ln,
                "config-drift",
                format!("`--{name}` is documented in {CONFIG_REL} but missing from cli::help_text"),
            ));
        }
    }
    let mut reported = BTreeSet::new();
    for (name, ln) in &offered {
        if !doc_set.contains(name.as_str()) && reported.insert(name.as_str()) {
            out.push(Violation::new(
                HELP_REL,
                *ln,
                "config-drift",
                format!("`--{name}` is in cli::help_text but undocumented in {CONFIG_REL}"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    /// A throwaway tree under the system temp dir.
    struct Fixture {
        root: PathBuf,
    }

    impl Fixture {
        fn new(name: &str) -> Fixture {
            let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
            let root = std::env::temp_dir()
                .join(format!("osmax-xtask-{}-{name}-{n}", std::process::id()));
            fs::create_dir_all(root.join("rust/src")).unwrap();
            Fixture { root }
        }

        fn write(&self, rel: &str, text: &str) {
            let p = self.root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, text).unwrap();
        }
    }

    impl Drop for Fixture {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    fn rules(vs: &[Violation]) -> BTreeSet<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn catches_unsafe_without_safety_comment() {
        let fx = Fixture::new("unsafe");
        fx.write("rust/src/a.rs", "pub fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n");
        let v = lint_tree(&fx.root);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unsafe-safety");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn safety_comment_above_let_statement_is_accepted() {
        let fx = Fixture::new("unsafe-ok");
        fx.write(
            "rust/src/a.rs",
            "pub fn f(p: *mut u8) -> u8 {\n    // SAFETY: caller owns p.\n    let v: u8 =\n        unsafe { *p };\n    v\n}\n",
        );
        assert!(lint_tree(&fx.root).is_empty());
    }

    #[test]
    fn word_unsafe_inside_identifiers_is_not_flagged() {
        let fx = Fixture::new("unsafe-word");
        fx.write("rust/src/a.rs", "#![warn(clippy::undocumented_unsafe_blocks)]\n");
        assert!(lint_tree(&fx.root).is_empty());
    }

    #[test]
    fn catches_ordering_outside_allowlist() {
        let fx = Fixture::new("atomics");
        fx.write(
            "rust/src/a.rs",
            "// xtask:atomics-allowlist: Relaxed\nfn f(x: &std::sync::atomic::AtomicUsize) {\n    x.store(1, Ordering::SeqCst);\n}\n",
        );
        let v = lint_tree(&fx.root);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "atomics-allowlist");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn catches_missing_allowlist_header_and_ignores_cmp_ordering() {
        let fx = Fixture::new("atomics-header");
        fx.write(
            "rust/src/a.rs",
            "fn f(x: &std::sync::atomic::AtomicUsize) -> std::cmp::Ordering {\n    x.store(1, Ordering::Relaxed);\n    std::cmp::Ordering::Less\n}\n",
        );
        let v = lint_tree(&fx.root);
        assert_eq!(v.len(), 1, "cmp::Ordering::Less must not need a header: {v:?}");
        assert!(v[0].message.contains("no"), "{v:?}");
    }

    #[test]
    fn catches_unwrap_in_serving_code_and_honors_waivers() {
        let fx = Fixture::new("panics");
        fx.write(
            "rust/src/coordinator/a.rs",
            concat!(
                "fn f(o: Option<u8>, m: &std::sync::Mutex<u8>) -> u8 {\n",
                "    let _fine = m.lock().unwrap();\n",
                "    // panic-ok: fixture waiver.\n",
                "    let _waived = o.expect(\"x\");\n",
                "    o.unwrap()\n",
                "}\n",
                "#[cfg(test)]\n",
                "mod tests {\n",
                "    fn g(o: Option<u8>) -> u8 {\n",
                "        o.unwrap()\n",
                "    }\n",
                "}\n",
            ),
        );
        // Same content outside server/coordinator: no rule applies.
        fx.write("rust/src/shard/a.rs", "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}\n");
        let v = lint_tree(&fx.root);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "no-panic");
        assert_eq!(v[0].line, 5);
        assert!(v[0].file.contains("coordinator"));
    }

    #[test]
    fn catches_config_drift_in_both_directions() {
        let fx = Fixture::new("drift");
        fx.write("docs/CONFIG.md", "| `--alpha N` | `alpha` | 1 | Seeded drift. |\n");
        fx.write(
            "rust/src/cli/mod.rs",
            "pub fn help_text(version: &str) -> String {\n    format!(\"usage [{version}]: thing --beta N\")\n}\n",
        );
        let v = lint_tree(&fx.root);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|v| v.message.contains("`--alpha`")), "{v:?}");
        assert!(v.iter().any(|v| v.message.contains("`--beta`")), "{v:?}");
    }

    #[test]
    fn self_test_tree_seeds_one_violation_per_class() {
        let fx = Fixture::new("all-classes");
        fx.write("rust/src/a.rs", "pub fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n");
        fx.write(
            "rust/src/b.rs",
            "fn f(x: &std::sync::atomic::AtomicUsize) {\n    x.store(1, Ordering::SeqCst);\n}\n",
        );
        fx.write("rust/src/server/a.rs", "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}\n");
        fx.write("docs/CONFIG.md", "| `--alpha N` | `alpha` | 1 | Seeded drift. |\n");
        fx.write(
            "rust/src/cli/mod.rs",
            "pub fn help_text(version: &str) -> String {\n    format!(\"usage [{version}]: thing --beta N\")\n}\n",
        );
        let v = lint_tree(&fx.root);
        let want: BTreeSet<&str> =
            ["unsafe-safety", "atomics-allowlist", "no-panic", "config-drift"]
                .into_iter()
                .collect();
        assert_eq!(rules(&v), want, "{v:?}");
    }

    #[test]
    fn real_tree_is_clean() {
        let v = lint_tree(&repo_root());
        let listing: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        assert!(v.is_empty(), "violations:\n{}", listing.join("\n"));
    }
}
