# Developer entry points.  `cargo build/test` work standalone (the host
# backend needs no artifacts); python is only needed for the AOT
# artifacts and for regenerating golden vectors.

.PHONY: build test bench golden artifacts fmt

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench shard_ablation

# Golden vectors for rust/tests/golden_vectors.rs (committed; regenerate
# after changing the python oracles or adding fixture cases).
golden:
	cd python && python3 -m compile.golden --out ../rust/tests/golden

# AOT-compile the PJRT artifacts (requires jax; see python/compile/aot.py).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt
